//! Partial-key cuckoo hashing: fingerprint + bucket-index derivation for
//! both bucket-placement policies (§2.1, §4.3 step 1, §4.6.2).
//!
//! Everything an operation needs is derived from the key's 64-bit xxHash:
//! the *upper* 32 bits feed the fingerprint and the *lower* 32 bits the
//! primary bucket index ("distinct hash parts are used to avoid
//! fingerprint clustering", §4.3).
//!
//! The two policies differ in how the alternate bucket is found and in
//! what is stored:
//!
//! * **XOR** (classic, Fan et al.): `i2 = i1 ^ H(fp)`; the stored tag is
//!   the fingerprint itself and the mapping is an involution, so a stored
//!   tag's alternate bucket is computable from its current bucket alone.
//!   Requires `m` to be a power of two.
//! * **Offset + choice bit** (Schmitz et al., §4.6.2): `i2 = (i1 +
//!   offset(fp)) mod m` for any `m`. The stored tag's MSB (the *choice
//!   bit*) records whether the item currently sits in its primary (0) or
//!   alternate (1) bucket, and is flipped on every relocation. One
//!   fingerprint bit is sacrificed.
//!
//! ## Growth slices (elastic capacity)
//!
//! A grown filter at growth level `g` has `m = m0 << g` buckets: `2^g`
//! *slices* of the base geometry `m0`. A tag's slice is chosen by the
//! low `g` bits of its effective fingerprint (`ext = fp & (2^g - 1)`)
//! and its within-slice index by the base derivation, so
//! `bucket = ext * m0 + low`. Both the alternate-bucket mapping and
//! eviction relocation operate on `low` only and preserve the slice —
//! which is what makes a stored tag *rehashable across geometries*: the
//! level-`g+1` bucket of any tag is computable from its level-`g`
//! bucket and the tag alone (`migrate_bucket`), no original key needed.
//! At `g = 0` every formula degenerates to the classic single-table
//! derivation bit-for-bit, and since queries always compare the full
//! stored tag, borrowing fingerprint bits for slice selection does not
//! change the false-positive rate.

use super::hash::xxhash64_u64;
use super::swar::Layout;
use crate::util::prng::mix64;

/// The two candidate placements of a key: `(bucket, stored_tag)` pairs.
/// `slots[0]` is the primary location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidates {
    pub primary: (usize, u64),
    pub alternate: (usize, u64),
}

/// Policy engine: resolves keys and stored tags to bucket locations.
/// All methods are branch-light and fully inlined into the filter ops.
#[derive(Clone, Copy, Debug)]
pub struct PolicyEngine<L: Layout> {
    pub num_buckets: u64,
    pub seed: u64,
    kind: super::config::BucketPolicy,
    /// Base (level-0) bucket count `m0`; `num_buckets = m0 << growth_level`.
    base_buckets: u64,
    /// Growth level `g`: how many times the geometry has doubled.
    growth_level: u32,
    /// `(1 << g) - 1`: low fingerprint bits selecting the slice.
    ext_mask: u64,
    /// `base_buckets - 1` when the base count is a power of two —
    /// strength-reduces the hot-path `% m0` to an AND (a 20-40 cycle
    /// saving per access on the integer divider).
    pow2_mask: Option<u64>,
    _marker: std::marker::PhantomData<L>,
}

impl<L: Layout> PolicyEngine<L> {
    pub fn new(kind: super::config::BucketPolicy, num_buckets: usize, seed: u64) -> Self {
        Self::with_growth(kind, num_buckets, 0, seed)
    }

    /// Policy engine for a grown geometry: `num_buckets` is the CURRENT
    /// total (`m0 << growth_level`). The caller (config validation)
    /// guarantees divisibility and that `growth_level` fits the
    /// effective fingerprint width.
    pub fn with_growth(
        kind: super::config::BucketPolicy,
        num_buckets: usize,
        growth_level: u32,
        seed: u64,
    ) -> Self {
        let base = (num_buckets >> growth_level) as u64;
        Self {
            num_buckets: num_buckets as u64,
            seed,
            kind,
            base_buckets: base,
            growth_level,
            ext_mask: (1u64 << growth_level) - 1,
            pow2_mask: (base as usize).is_power_of_two().then(|| base - 1),
            _marker: std::marker::PhantomData,
        }
    }

    /// `x mod base_buckets`, as an AND when the base count is a power of
    /// two. All index derivation happens in the base slice; the slice
    /// offset is added afterwards.
    #[inline(always)]
    fn mod_base(&self, x: u64) -> u64 {
        match self.pow2_mask {
            Some(mask) => x & mask,
            None => x % self.base_buckets,
        }
    }

    /// Slice offset of an effective fingerprint: the low `g` bits of
    /// the fingerprint pick one of the `2^g` base-geometry slices.
    #[inline(always)]
    fn slice_of(&self, fp: u64) -> u64 {
        (fp & self.ext_mask) * self.base_buckets
    }

    pub fn growth_level(&self) -> u32 {
        self.growth_level
    }

    pub fn base_buckets(&self) -> u64 {
        self.base_buckets
    }

    pub fn kind(&self) -> super::config::BucketPolicy {
        self.kind
    }

    /// Fingerprint mask for the *effective* fingerprint (excluding the
    /// choice bit under the offset policy).
    #[inline(always)]
    pub fn fp_mask(&self) -> u64 {
        match self.kind {
            super::config::BucketPolicy::Xor => L::LANE_MASK,
            super::config::BucketPolicy::Offset => L::LANE_MASK >> 1,
        }
    }

    /// Choice-bit position (offset policy): lane MSB.
    #[inline(always)]
    fn choice_bit(&self) -> u64 {
        (L::LANE_MASK >> 1) + 1
    }

    /// Derive the fingerprint from the hash's upper half. Never returns 0
    /// (0 encodes an empty slot).
    #[inline(always)]
    pub fn fingerprint(&self, h: u64) -> u64 {
        let fp = (h >> 32) & self.fp_mask();
        fp + (fp == 0) as u64
    }

    /// The XOR policy's `H(fp)` / the offset policy's `offset(fp)`.
    #[inline(always)]
    fn fp_spread(&self, fp: u64) -> u64 {
        mix64(fp ^ self.seed)
    }

    /// Offset in `[1, m0-1]` — never 0 so the two candidates differ
    /// whenever `m0 > 1`. Offsets stay within the base slice so the
    /// alternate bucket shares the primary's slice.
    #[inline(always)]
    fn offset_of(&self, fp: u64) -> u64 {
        1 + self.fp_spread(fp) % (self.base_buckets - 1)
    }

    /// Resolve a key to its two candidate `(bucket, stored_tag)` slots.
    #[inline(always)]
    pub fn candidates(&self, key: u64) -> Candidates {
        let h = xxhash64_u64(key, self.seed);
        let fp = self.fingerprint(h);
        let slice = self.slice_of(fp);
        let i1 = self.mod_base(h & 0xFFFF_FFFF);
        match self.kind {
            super::config::BucketPolicy::Xor => {
                let i2 = i1 ^ self.mod_base(self.fp_spread(fp));
                Candidates {
                    primary: ((slice + i1) as usize, fp),
                    alternate: ((slice + i2) as usize, fp),
                }
            }
            super::config::BucketPolicy::Offset => {
                let i2 = (i1 + self.offset_of(fp)) % self.base_buckets;
                Candidates {
                    primary: ((slice + i1) as usize, fp),
                    alternate: ((slice + i2) as usize, fp | self.choice_bit()),
                }
            }
        }
    }

    /// Where does a *stored* tag go when evicted from `bucket`, and what
    /// is stored there? (Alg. 1 line 21 / §4.6.2 choice-bit flip.)
    /// Relocation moves within the bucket's slice only.
    #[inline(always)]
    pub fn relocate(&self, stored_tag: u64, bucket: usize) -> (usize, u64) {
        let low = self.mod_base(bucket as u64);
        let slice = bucket as u64 - low;
        match self.kind {
            super::config::BucketPolicy::Xor => {
                let alt = low ^ self.mod_base(self.fp_spread(stored_tag));
                ((slice + alt) as usize, stored_tag)
            }
            super::config::BucketPolicy::Offset => {
                let choice = stored_tag & self.choice_bit();
                let fp = stored_tag & self.fp_mask();
                let m = self.base_buckets;
                let off = self.offset_of(fp);
                if choice == 0 {
                    // Currently in primary; moves to alternate.
                    let alt = (low + off) % m;
                    ((slice + alt) as usize, fp | self.choice_bit())
                } else {
                    // Currently in alternate; moves back to primary.
                    let prim = (low + m - off % m) % m;
                    ((slice + prim) as usize, fp)
                }
            }
        }
    }

    /// Level-(g+1) bucket of a tag stored in `old_bucket` of a level-g
    /// geometry with the same base: the slice gains fingerprint bit `g`,
    /// the within-slice index is preserved. This is the whole migration
    /// map — collision-free (each new bucket receives tags from exactly
    /// one old bucket) and computable from the stored tag alone.
    #[inline(always)]
    pub fn migrate_bucket(&self, stored_tag: u64, old_bucket: usize) -> usize {
        debug_assert!(self.growth_level > 0, "migrate_bucket needs the grown policy");
        let low = self.mod_base(old_bucket as u64);
        (self.slice_of(stored_tag & self.fp_mask()) + low) as usize
    }

    /// Memory footprint note for benches: bits of fingerprint entropy.
    pub fn effective_fp_bits(&self) -> u32 {
        match self.kind {
            super::config::BucketPolicy::Xor => L::FP_BITS,
            super::config::BucketPolicy::Offset => L::FP_BITS - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::config::BucketPolicy;
    use crate::filter::swar::{Fp16, Fp8};

    #[test]
    fn xor_relocation_is_involution() {
        let eng = PolicyEngine::<Fp16>::new(BucketPolicy::Xor, 1 << 12, 1);
        let mut rng = crate::util::SplitMix64::new(3);
        for _ in 0..10_000 {
            let key = rng.next_u64();
            let c = eng.candidates(key);
            // relocate(primary) == alternate and vice versa.
            assert_eq!(
                eng.relocate(c.primary.1, c.primary.0),
                (c.alternate.0, c.alternate.1)
            );
            assert_eq!(
                eng.relocate(c.alternate.1, c.alternate.0),
                (c.primary.0, c.primary.1)
            );
        }
    }

    #[test]
    fn offset_relocation_roundtrip() {
        for m in [1000usize, 1 << 12, 12345, 7] {
            let eng = PolicyEngine::<Fp16>::new(BucketPolicy::Offset, m, 99);
            let mut rng = crate::util::SplitMix64::new(4);
            for _ in 0..10_000 {
                let key = rng.next_u64();
                let c = eng.candidates(key);
                assert!(c.primary.0 < m && c.alternate.0 < m);
                assert_eq!(
                    eng.relocate(c.primary.1, c.primary.0),
                    (c.alternate.0, c.alternate.1),
                    "m={m}"
                );
                assert_eq!(
                    eng.relocate(c.alternate.1, c.alternate.0),
                    (c.primary.0, c.primary.1),
                    "m={m}"
                );
                // Double relocation returns to start.
                let (b1, t1) = eng.relocate(c.primary.1, c.primary.0);
                let (b2, t2) = eng.relocate(t1, b1);
                assert_eq!((b2, t2), (c.primary.0, c.primary.1));
            }
        }
    }

    #[test]
    fn fingerprint_never_zero() {
        let eng = PolicyEngine::<Fp8>::new(BucketPolicy::Xor, 1 << 10, 0);
        for h in 0..200_000u64 {
            assert_ne!(eng.fingerprint(h << 32), 0);
        }
        let eng = PolicyEngine::<Fp8>::new(BucketPolicy::Offset, 1000, 0);
        for h in 0..200_000u64 {
            let fp = eng.fingerprint(h << 32);
            assert_ne!(fp, 0);
            assert!(fp <= eng.fp_mask());
        }
    }

    #[test]
    fn offset_candidates_differ() {
        let eng = PolicyEngine::<Fp16>::new(BucketPolicy::Offset, 977, 5);
        let mut rng = crate::util::SplitMix64::new(8);
        for _ in 0..5_000 {
            let c = eng.candidates(rng.next_u64());
            assert_ne!(c.primary.0, c.alternate.0);
            // Stored tags differ exactly in the choice bit.
            assert_eq!(c.primary.1 | (1 << 15), c.alternate.1);
        }
    }

    #[test]
    fn effective_bits() {
        let x = PolicyEngine::<Fp16>::new(BucketPolicy::Xor, 1 << 4, 0);
        let o = PolicyEngine::<Fp16>::new(BucketPolicy::Offset, 17, 0);
        assert_eq!(x.effective_fp_bits(), 16);
        assert_eq!(o.effective_fp_bits(), 15);
    }

    #[test]
    fn grown_geometry_keeps_relocation_properties_and_slices() {
        // At every growth level, both policies keep the involution /
        // roundtrip property, candidates stay inside one slice, and the
        // within-slice (base) index is exactly the level-0 derivation.
        for g in 1..=4u32 {
            for (kind, m0) in [(BucketPolicy::Xor, 1usize << 10), (BucketPolicy::Offset, 977)] {
                let base = PolicyEngine::<Fp16>::new(kind, m0, 42);
                let eng = PolicyEngine::<Fp16>::with_growth(kind, m0 << g, g, 42);
                assert_eq!(eng.base_buckets(), m0 as u64);
                assert_eq!(eng.growth_level(), g);
                let mut rng = crate::util::SplitMix64::new(g as u64);
                for _ in 0..5_000 {
                    let key = rng.next_u64();
                    let c = eng.candidates(key);
                    let c0 = base.candidates(key);
                    // Same tag, same within-slice indices, one slice.
                    assert_eq!(c.primary.1, c0.primary.1);
                    assert_eq!(c.primary.0 % m0, c0.primary.0);
                    assert_eq!(c.alternate.0 % m0, c0.alternate.0);
                    assert_eq!(c.primary.0 / m0, c.alternate.0 / m0, "slice split");
                    assert!(c.alternate.0 < m0 << g);
                    assert_eq!(
                        eng.relocate(c.primary.1, c.primary.0),
                        (c.alternate.0, c.alternate.1)
                    );
                    assert_eq!(
                        eng.relocate(c.alternate.1, c.alternate.0),
                        (c.primary.0, c.primary.1)
                    );
                }
            }
        }
    }

    #[test]
    fn migrate_bucket_matches_the_grown_candidate_derivation() {
        // Migrating a tag from its level-g bucket into level g+1 must
        // land it exactly where the level-(g+1) candidate derivation
        // would place that key — for primary AND alternate placements.
        for kind in [BucketPolicy::Xor, BucketPolicy::Offset] {
            let m0 = match kind {
                BucketPolicy::Xor => 1usize << 9,
                BucketPolicy::Offset => 1000,
            };
            for g in 0..3u32 {
                let old = PolicyEngine::<Fp16>::with_growth(kind, m0 << g, g, 7);
                let new = PolicyEngine::<Fp16>::with_growth(kind, m0 << (g + 1), g + 1, 7);
                let mut rng = crate::util::SplitMix64::new(77 + g as u64);
                for _ in 0..5_000 {
                    let key = rng.next_u64();
                    let (oc, nc) = (old.candidates(key), new.candidates(key));
                    assert_eq!(new.migrate_bucket(oc.primary.1, oc.primary.0), nc.primary.0);
                    assert_eq!(
                        new.migrate_bucket(oc.alternate.1, oc.alternate.0),
                        nc.alternate.0
                    );
                }
            }
        }
    }

    #[test]
    fn xor_indices_in_range() {
        // m power of two: i1 ^ (spread % m) < m requires i1 < m and spread%m < m
        // — XOR of two values below a power of two stays below it.
        let m = 1 << 14;
        let eng = PolicyEngine::<Fp16>::new(BucketPolicy::Xor, m, 77);
        let mut rng = crate::util::SplitMix64::new(10);
        for _ in 0..10_000 {
            let c = eng.candidates(rng.next_u64());
            assert!(c.primary.0 < m);
            assert!(c.alternate.0 < m);
        }
    }
}
