//! Pre-sorted insertion (§4.6.3): order the batch by primary bucket index
//! with a radix sort before launching, so neighbouring logical threads
//! touch neighbouring buckets. The paper found the sort does not amortise
//! on HBM-class parts; we keep it for the ablation bench (it *is* a win in
//! the gpusim GDDR model at large batch sizes, and on CPUs it improves
//! cache locality measurably).

use super::core::CuckooFilter;
use super::swar::Layout;
use crate::device::Device;
use crate::op::OpKind;

/// LSD radix sort of `(bucket, key)` pairs by bucket index, 8 bits per
/// pass — the CPU stand-in for CUB's `DeviceRadixSort`.
pub fn radix_sort_by_bucket(pairs: &mut Vec<(u32, u64)>) {
    let n = pairs.len();
    if n <= 1 {
        return;
    }
    let max_bucket = pairs.iter().map(|p| p.0).max().unwrap_or(0);
    let passes = (32 - max_bucket.leading_zeros()).div_ceil(8).max(1);
    let mut scratch: Vec<(u32, u64)> = vec![(0, 0); n];
    let mut src_is_pairs = true;
    for pass in 0..passes {
        let shift = pass * 8;
        let (src, dst): (&[(u32, u64)], &mut [(u32, u64)]) = if src_is_pairs {
            (&pairs[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut pairs[..])
        };
        let mut counts = [0usize; 256];
        for p in src {
            counts[((p.0 >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for i in 0..256 {
            offsets[i] = acc;
            acc += counts[i];
        }
        for p in src {
            let d = ((p.0 >> shift) & 0xFF) as usize;
            dst[offsets[d]] = *p;
            offsets[d] += 1;
        }
        src_is_pairs = !src_is_pairs;
    }
    if !src_is_pairs {
        pairs.copy_from_slice(&scratch);
    }
}

impl<L: Layout> CuckooFilter<L> {
    /// Sorted-insertion variant: radix-sort the batch by primary bucket
    /// index, then insert in that order. Returns the same accept tally
    /// as `execute_batch(.., OpKind::Insert, ..)` plus the sort time
    /// share, so benches can report the amortisation trade-off the paper
    /// discusses. (An insert-ordering ablation, not an execution surface
    /// — ordering is meaningless for queries and deletes, so this stays
    /// a named variant outside the `OpKind` dispatch.)
    pub fn insert_batch_sorted(&self, device: &Device, keys: &[u64]) -> (u64, f64) {
        let t = crate::util::Timer::new();
        let mut pairs: Vec<(u32, u64)> = keys
            .iter()
            .map(|&k| (self.policy().candidates(k).primary.0 as u32, k))
            .collect();
        radix_sort_by_bucket(&mut pairs);
        let sorted_keys: Vec<u64> = pairs.into_iter().map(|(_, k)| k).collect();
        let sort_secs = t.elapsed_secs();
        let inserted = self.execute_batch(device, OpKind::Insert, &sorted_keys, None);
        (inserted, sort_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::config::CuckooConfig;
    use crate::filter::swar::Fp16;
    use crate::util::prng::mix64;

    #[test]
    fn radix_sort_sorts() {
        let mut rng = crate::util::SplitMix64::new(1);
        let mut pairs: Vec<(u32, u64)> = (0..10_000)
            .map(|_| ((rng.next_u64() >> 40) as u32, rng.next_u64()))
            .collect();
        let mut expect = pairs.clone();
        expect.sort_by_key(|p| p.0);
        radix_sort_by_bucket(&mut pairs);
        let got: Vec<u32> = pairs.iter().map(|p| p.0).collect();
        let want: Vec<u32> = expect.iter().map(|p| p.0).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn radix_sort_is_stable_permutation() {
        let mut pairs = vec![(3u32, 30u64), (1, 10), (3, 31), (0, 0), (1, 11)];
        radix_sort_by_bucket(&mut pairs);
        assert_eq!(pairs, vec![(0, 0), (1, 10), (1, 11), (3, 30), (3, 31)]);
    }

    #[test]
    fn radix_sort_empty_and_single() {
        let mut v: Vec<(u32, u64)> = vec![];
        radix_sort_by_bucket(&mut v);
        assert!(v.is_empty());
        let mut v = vec![(5u32, 55u64)];
        radix_sort_by_bucket(&mut v);
        assert_eq!(v, vec![(5, 55)]);
    }

    #[test]
    fn sorted_insert_equivalent_results() {
        let device = Device::with_workers(4);
        let keys: Vec<u64> = (0..20_000u64).map(mix64).collect();

        let plain = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(20_000)).unwrap();
        plain.execute_batch(&device, crate::op::OpKind::Insert, &keys, None);

        let sorted = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(20_000)).unwrap();
        let (inserted, sort_secs) = sorted.insert_batch_sorted(&device, &keys);
        assert_eq!(inserted, 20_000);
        assert!(sort_secs >= 0.0);

        // Same membership answers afterwards.
        for &k in keys.iter().take(5_000) {
            assert!(plain.contains(k) && sorted.contains(k));
        }
    }
}
