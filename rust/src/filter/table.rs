//! The bucket table: one contiguous, cache-line-aligned array of
//! `AtomicU64` words in which all fingerprints live (§4.2, Figure 2).
//!
//! All mutation goes through 64-bit compare-and-swap on these words; reads
//! on the query path are relaxed loads (the paper's non-coherent vector
//! loads — queries are only safe when not concurrent with mutations, and
//! the [`crate::coordinator`] enforces that phase separation).

use std::sync::atomic::{AtomicU64, Ordering};

/// 64-byte aligned chunk so buckets start on cache-line boundaries, the
/// CPU analogue of the GPU's 128-byte-aligned allocation.
#[repr(C, align(64))]
struct CacheLine([AtomicU64; 8]);

pub struct Table {
    lines: Box<[CacheLine]>,
    num_words: usize,
    pub words_per_bucket: usize,
    pub num_buckets: usize,
}

impl Table {
    pub fn new(num_buckets: usize, words_per_bucket: usize) -> Self {
        let num_words = num_buckets * words_per_bucket;
        let num_lines = num_words.div_ceil(8).max(1);
        let mut v = Vec::with_capacity(num_lines);
        for _ in 0..num_lines {
            v.push(CacheLine(Default::default()));
        }
        Self {
            lines: v.into_boxed_slice(),
            num_words,
            words_per_bucket,
            num_buckets,
        }
    }

    #[inline(always)]
    fn word(&self, idx: usize) -> &AtomicU64 {
        debug_assert!(idx < self.num_words);
        &self.lines[idx >> 3].0[idx & 7]
    }

    /// Raw pointer to a word, for prefetch hints only.
    #[inline(always)]
    pub fn word_ptr(&self, idx: usize) -> *const AtomicU64 {
        self.word(idx) as *const AtomicU64
    }

    /// Global word index of word `w` in bucket `b`.
    #[inline(always)]
    pub fn word_index(&self, bucket: usize, w: usize) -> usize {
        bucket * self.words_per_bucket + w
    }

    /// Relaxed (non-coherent) load — the query path's vectorised read.
    #[inline(always)]
    pub fn load(&self, idx: usize) -> u64 {
        self.word(idx).load(Ordering::Relaxed)
    }

    /// Acquire load used before CAS attempts.
    #[inline(always)]
    pub fn load_acquire(&self, idx: usize) -> u64 {
        self.word(idx).load(Ordering::Acquire)
    }

    /// The one write primitive: compare-and-swap a whole word.
    /// Returns `Ok(())` on success, `Err(current)` on failure.
    #[inline(always)]
    pub fn cas(&self, idx: usize, expected: u64, desired: u64) -> Result<(), u64> {
        self.word(idx)
            .compare_exchange(expected, desired, Ordering::AcqRel, Ordering::Acquire)
            .map(|_| ())
    }

    /// Non-atomic store, only for construction/reset paths.
    pub fn store(&self, idx: usize, value: u64) {
        self.word(idx).store(value, Ordering::Release);
    }

    pub fn num_words(&self) -> usize {
        self.num_words
    }

    /// Size of the fingerprint storage in bytes.
    pub fn bytes(&self) -> usize {
        self.num_words * 8
    }

    /// Copy the whole table out (feeds the AOT query artifact and tests).
    pub fn snapshot(&self) -> Vec<u64> {
        (0..self.num_words).map(|i| self.load(i)).collect()
    }

    /// Zero every word.
    pub fn clear(&self) {
        for i in 0..self.num_words {
            self.store(i, 0);
        }
    }

    /// Count occupied slots by scanning (exact; O(words)). Used to verify
    /// the hierarchical occupancy counter.
    pub fn count_occupied<L: super::swar::Layout>(&self) -> usize {
        (0..self.num_words)
            .map(|i| L::count_occupied(self.load(i)) as usize)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::swar::{Fp16, Layout};

    #[test]
    fn alignment() {
        let t = Table::new(64, 4);
        let p = t.word(0) as *const AtomicU64 as usize;
        assert_eq!(p % 64, 0, "table must start cache-line aligned");
    }

    #[test]
    fn cas_semantics() {
        let t = Table::new(4, 4);
        assert_eq!(t.load(3), 0);
        t.cas(3, 0, 42).unwrap();
        assert_eq!(t.load(3), 42);
        assert_eq!(t.cas(3, 0, 7), Err(42));
        assert_eq!(t.load(3), 42);
    }

    #[test]
    fn word_indexing() {
        let t = Table::new(10, 4);
        assert_eq!(t.word_index(0, 0), 0);
        assert_eq!(t.word_index(2, 3), 11);
        assert_eq!(t.num_words(), 40);
        assert_eq!(t.bytes(), 320);
    }

    #[test]
    fn snapshot_and_clear() {
        let t = Table::new(2, 2);
        t.store(0, 1);
        t.store(3, 0xFFFF);
        assert_eq!(t.snapshot(), vec![1, 0, 0, 0xFFFF]);
        assert_eq!(t.count_occupied::<Fp16>(), 1 + 1);
        t.clear();
        assert_eq!(t.snapshot(), vec![0, 0, 0, 0]);
    }

    #[test]
    fn count_occupied_matches_layout() {
        let t = Table::new(1, 1);
        let w = Fp16::replace(Fp16::replace(0, 0, 5), 2, 9);
        t.store(0, w);
        assert_eq!(t.count_occupied::<Fp16>(), 2);
    }
}
