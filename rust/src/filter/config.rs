//! Filter configuration — the runtime analogue of the paper's single
//! template configuration structure (§4.7). The tag width is a
//! compile-time type parameter ([`crate::filter::Layout`]); everything
//! else lives here.

use super::error::FilterError;

/// Which partial-key scheme maps fingerprints to their alternate bucket
/// (§2.1 / §4.6.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BucketPolicy {
    /// Classic `i2 = i1 ^ H(fp)`; requires a power-of-two bucket count.
    Xor,
    /// Offset + choice-bit policy (Schmitz et al.): `i2 = i1 + offset(fp)
    /// mod m`, any `m`; costs one fingerprint bit for the choice flag.
    Offset,
}

impl BucketPolicy {
    pub fn name(self) -> &'static str {
        match self {
            BucketPolicy::Xor => "xor",
            BucketPolicy::Offset => "offset",
        }
    }
}

/// Eviction strategy (§4.3 step 3 vs §4.6.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EvictionPolicy {
    /// Greedy depth-first: evict one random victim and chase its chain.
    Dfs,
    /// Breadth-first heuristic: inspect up to `b/2` victims, prefer one
    /// whose alternate bucket has a free slot (two-step lock-free
    /// relocation with undo).
    Bfs,
}

impl EvictionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            EvictionPolicy::Dfs => "dfs",
            EvictionPolicy::Bfs => "bfs",
        }
    }
}

/// Emulated vector-load width for the read-only query path (§4.4):
/// 1 word = plain 64-bit loads, 2 words = 128-bit, 4 words = 256-bit
/// (`ld.global.nc.v4.u64` on Blackwell).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoadWidth {
    W64 = 1,
    W128 = 2,
    W256 = 4,
}

impl LoadWidth {
    pub fn words(self) -> usize {
        self as usize
    }
}

/// Per-namespace elastic-capacity policy (PR 8): when a shard's ledger
/// crosses `threshold` of its slots, the shard grows one level (bucket
/// count doubles, entries migrate into growth slices — see
/// [`crate::filter::policy`] module docs). `max_levels = 0` disables
/// growth entirely (the pre-PR-8 fixed-capacity behaviour).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GrowthConfig {
    /// Load factor α that triggers a growth step. Must be in (0, 1].
    pub threshold: f64,
    /// Maximum growth levels above the base geometry (capacity scales by
    /// `2^max_levels`). Also clamped at runtime to the fingerprint width
    /// so a slice index never consumes the whole tag.
    pub max_levels: usize,
}

impl Default for GrowthConfig {
    /// Grow at α = 0.9, up to 256× the provisioned capacity.
    fn default() -> Self {
        Self {
            threshold: 0.9,
            max_levels: 8,
        }
    }
}

impl GrowthConfig {
    /// Fixed capacity: never grow (shards saturate with `TooFull` as
    /// before).
    pub fn disabled() -> Self {
        Self {
            threshold: 1.0,
            max_levels: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_levels > 0
    }

    pub fn validate(&self) -> Result<(), FilterError> {
        if !(self.threshold > 0.0 && self.threshold <= 1.0) {
            return Err(FilterError::BadConfig(format!(
                "growth threshold must be in (0, 1], got {}",
                self.threshold
            )));
        }
        Ok(())
    }
}

/// Full filter configuration.
#[derive(Clone, Copy, Debug)]
pub struct CuckooConfig {
    /// Number of buckets (`m`). Power of two required for [`BucketPolicy::Xor`].
    /// For a grown filter this is the CURRENT total, `m0 << growth_level`.
    pub num_buckets: usize,
    /// Slots (tags) per bucket (`b`). The paper's GPU default is 16.
    pub bucket_slots: usize,
    pub policy: BucketPolicy,
    pub eviction: EvictionPolicy,
    /// Maximum evictions before an insert reports failure (Alg. 1).
    pub max_evictions: usize,
    /// Query vector-load width.
    pub load_width: LoadWidth,
    /// Hash seed baked into all derived values.
    pub seed: u64,
    /// Elastic-capacity level `g`: the geometry has been doubled `g`
    /// times from a base of `num_buckets >> g` buckets. 0 for filters
    /// that have never grown — all pre-PR-8 configs.
    pub growth_level: usize,
}

impl CuckooConfig {
    /// Paper defaults: b = 16 slots, XOR policy, BFS eviction, 500-step
    /// eviction budget, 256-bit loads.
    pub fn new(num_buckets: usize) -> Self {
        Self {
            num_buckets,
            bucket_slots: 16,
            policy: BucketPolicy::Xor,
            eviction: EvictionPolicy::Bfs,
            max_evictions: 500,
            load_width: LoadWidth::W256,
            seed: super::hash::DEFAULT_SEED,
            growth_level: 0,
        }
    }

    /// Size the filter for `capacity` items at a 95% design load factor,
    /// rounding buckets up to a power of two (XOR policy constraint §4.6.2).
    pub fn with_capacity(capacity: usize) -> Self {
        let slots_needed = (capacity as f64 / 0.95).ceil() as usize;
        let buckets = slots_needed.div_ceil(16).next_power_of_two();
        Self::new(buckets)
    }

    /// Same, but for the Offset policy: any bucket count is allowed, so no
    /// power-of-two rounding — this is the policy's whole point.
    pub fn with_capacity_offset(capacity: usize) -> Self {
        let slots_needed = (capacity as f64 / 0.95).ceil() as usize;
        let mut cfg = Self::new(slots_needed.div_ceil(16).max(2));
        cfg.policy = BucketPolicy::Offset;
        cfg
    }

    pub fn bucket_slots(mut self, b: usize) -> Self {
        self.bucket_slots = b;
        self
    }

    pub fn policy(mut self, p: BucketPolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn eviction(mut self, e: EvictionPolicy) -> Self {
        self.eviction = e;
        self
    }

    pub fn max_evictions(mut self, n: usize) -> Self {
        self.max_evictions = n;
        self
    }

    pub fn load_width(mut self, w: LoadWidth) -> Self {
        self.load_width = w;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Set the growth level, keeping `num_buckets` as the CURRENT total
    /// (so `base_buckets()` is `num_buckets >> g`). Used when loading a
    /// persisted grown image; live growth goes through [`Self::grown`].
    pub fn growth_level(mut self, g: usize) -> Self {
        self.growth_level = g;
        self
    }

    /// The geometry one growth level up: bucket count doubled, level
    /// incremented, everything else identical.
    pub fn grown(mut self) -> Self {
        self.num_buckets *= 2;
        self.growth_level += 1;
        self
    }

    /// Base (level-0) bucket count `m0`; `num_buckets = m0 << growth_level`.
    pub fn base_buckets(&self) -> usize {
        self.num_buckets >> self.growth_level
    }

    /// Total slot count.
    pub fn total_slots(&self) -> usize {
        self.num_buckets * self.bucket_slots
    }

    /// Validate against a tag layout with `fp_bits`-wide fingerprints.
    pub fn validate(&self, fp_bits: u32) -> Result<(), FilterError> {
        if self.num_buckets < 2 {
            return Err(FilterError::BadConfig("need at least 2 buckets".into()));
        }
        if self.policy == BucketPolicy::Xor && !self.num_buckets.is_power_of_two() {
            return Err(FilterError::BadConfig(format!(
                "XOR policy requires a power-of-two bucket count, got {}",
                self.num_buckets
            )));
        }
        // Growth slices borrow the low `growth_level` fingerprint bits
        // as a slice index (see filter/policy.rs): the base geometry
        // must divide out exactly and at least one fingerprint bit must
        // remain above the slice index.
        let effective_fp_bits = match self.policy {
            BucketPolicy::Xor => fp_bits,
            BucketPolicy::Offset => fp_bits.saturating_sub(1),
        };
        if self.growth_level >= effective_fp_bits as usize {
            return Err(FilterError::BadConfig(format!(
                "growth level {} exhausts the {}-bit effective fingerprint",
                self.growth_level, effective_fp_bits
            )));
        }
        let base = self.num_buckets >> self.growth_level;
        if base << self.growth_level != self.num_buckets || base < 2 {
            return Err(FilterError::BadConfig(format!(
                "growth level {} does not divide {} buckets into a base of >= 2",
                self.growth_level, self.num_buckets
            )));
        }
        let tags_per_word = (64 / fp_bits) as usize;
        if self.bucket_slots == 0 || self.bucket_slots % tags_per_word != 0 {
            return Err(FilterError::BadConfig(format!(
                "bucket_slots ({}) must be a positive multiple of tags-per-word ({tags_per_word})",
                self.bucket_slots
            )));
        }
        if self.policy == BucketPolicy::Offset && fp_bits < 2 {
            return Err(FilterError::BadConfig(
                "offset policy needs at least 2 fingerprint bits".into(),
            ));
        }
        let words_per_bucket = self.bucket_slots / tags_per_word;
        if self.load_width.words() > words_per_bucket
            && self.load_width.words() % words_per_bucket != 0
        {
            // Wide loads wrap across buckets only in whole-bucket multiples.
            return Err(FilterError::BadConfig(format!(
                "load width {} words incompatible with {} words per bucket",
                self.load_width.words(),
                words_per_bucket
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_sizing() {
        let cfg = CuckooConfig::with_capacity(1_000_000);
        assert!(cfg.num_buckets.is_power_of_two());
        // Must hold 1M at <= 95% load.
        assert!(cfg.total_slots() as f64 * 0.95 >= 1_000_000.0);
        cfg.validate(16).unwrap();
    }

    #[test]
    fn offset_capacity_not_rounded() {
        let cfg = CuckooConfig::with_capacity_offset(1_000_000);
        assert_eq!(cfg.policy, BucketPolicy::Offset);
        // Offset sizing should be much tighter than the next power of two.
        let xor = CuckooConfig::with_capacity(1_000_000);
        assert!(cfg.total_slots() <= xor.total_slots());
        cfg.validate(16).unwrap();
    }

    #[test]
    fn xor_rejects_non_pow2() {
        let cfg = CuckooConfig::new(1000);
        assert!(cfg.validate(16).is_err());
        let cfg = cfg.policy(BucketPolicy::Offset);
        cfg.validate(16).unwrap();
    }

    #[test]
    fn growth_level_geometry_and_validation() {
        let cfg = CuckooConfig::new(1 << 8).growth_level(3); // base 32
        cfg.validate(16).unwrap();
        assert_eq!(cfg.base_buckets(), 32);
        // grown() doubles the total and bumps the level; base unchanged.
        let g = cfg.grown();
        assert_eq!(g.num_buckets, 1 << 9);
        assert_eq!(g.growth_level, 4);
        assert_eq!(g.base_buckets(), 32);
        g.validate(16).unwrap();
        // A level that leaves a base under 2 is rejected.
        assert!(CuckooConfig::new(4).growth_level(2).validate(16).is_err());
        // A level that exhausts the effective fingerprint is rejected
        // (fp8 offset: 7 effective bits after the choice flag).
        assert!(CuckooConfig::new(1 << 9)
            .policy(BucketPolicy::Offset)
            .growth_level(7)
            .validate(8)
            .is_err());
        // GrowthConfig sanity.
        GrowthConfig::default().validate().unwrap();
        assert!((GrowthConfig::default().threshold - 0.9).abs() < 1e-9);
        assert!(GrowthConfig {
            threshold: 0.0,
            max_levels: 4
        }
        .validate()
        .is_err());
        assert!(!GrowthConfig::disabled().enabled());
    }

    #[test]
    fn bucket_slots_must_fill_words() {
        let cfg = CuckooConfig::new(1024).bucket_slots(3);
        assert!(cfg.validate(16).is_err()); // 4 tags/word for fp16
        let cfg = CuckooConfig::new(1024).bucket_slots(8);
        cfg.validate(16).unwrap();
    }
}
