//! The Cuckoo-GPU filter core: packed SWAR buckets, lock-free CAS
//! mutation, DFS/BFS eviction and both bucket-placement policies.
//!
//! Module map (one file per concern, mirroring §4 of the paper):
//! * [`hash`] — xxHash64 (§4.3 step 1);
//! * [`swar`] — packed-word lane operations (§4.2);
//! * [`policy`] — partial-key hashing, XOR and offset/choice-bit (§2.1, §4.6.2);
//! * [`table`] — the atomic word array (§4.2, Fig. 2);
//! * [`core`] — Algorithms 1–3 + BFS eviction (§4.3–§4.6.1), plus the
//!   elastic-capacity generation machinery (PR 8): a filter is a sparse
//!   array of immutable-geometry generations, grown one level at a time
//!   by migrating tags into growth slices (see [`policy`]) and
//!   atomically publishing the new table;
//! * [`batch`] — the device-wide batch entry point (§4.3 "parallel
//!   insertion"): one `execute_batch(backend, OpKind, keys, out)` for
//!   all three ops;
//! * [`sorted`] — the pre-sorted insertion variant (§4.6.3);
//! * [`persist`] — save/load filter images (rebuild-free index reuse);
//! * [`probe`] — memory-access tracing for gpusim and Figure 5.

pub mod hash;
pub mod swar;
pub mod config;
pub mod error;
pub mod policy;
pub mod table;
pub mod probe;
pub mod core;
pub mod batch;
pub mod sorted;
pub mod persist;

pub use config::{BucketPolicy, CuckooConfig, EvictionPolicy, GrowthConfig, LoadWidth};
pub use core::CuckooFilter;
pub use error::FilterError;
pub use probe::{NoProbe, Probe, TraceProbe};
pub use swar::{Fp16, Fp32, Fp8, Layout};
