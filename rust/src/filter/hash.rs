//! xxHash64 — the paper's hash function (§4.3 step 1): "Each item is first
//! hashed into a 64-bit value using the xxHash64 algorithm, chosen for its
//! high performance and excellent statistical properties."
//!
//! Two entry points:
//! * [`xxhash64`] — the full streaming algorithm over byte slices (used by
//!   the k-mer pipeline and for arbitrary keys);
//! * [`xxhash64_u64`] — the specialised fixed-8-byte path used on the hot
//!   path for `u64` keys. It is *exactly* `xxhash64(&key.to_le_bytes(), seed)`
//!   but fully unrolled and branch-free.
//!
//! The Python build path (`python/compile/kernels/hash_kernel.py`)
//! implements the same fixed-width variant; golden vectors below pin both
//! sides to the reference implementation.

const PRIME64_1: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME64_2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME64_3: u64 = 0x1656_67B1_9E37_79F9;
const PRIME64_4: u64 = 0x85EB_CA77_C2B2_AE63;
const PRIME64_5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline(always)]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME64_2))
        .rotate_left(31)
        .wrapping_mul(PRIME64_1)
}

#[inline(always)]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val))
        .wrapping_mul(PRIME64_1)
        .wrapping_add(PRIME64_4)
}

#[inline(always)]
fn avalanche(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^= h >> 32;
    h
}

#[inline(always)]
fn read_u64(b: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(b[i..i + 8].try_into().unwrap())
}

#[inline(always)]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(b[i..i + 4].try_into().unwrap())
}

/// Full xxHash64 over a byte slice.
pub fn xxhash64(input: &[u8], seed: u64) -> u64 {
    let len = input.len();
    let mut h: u64;
    let mut i = 0usize;

    if len >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while i + 32 <= len {
            v1 = round(v1, read_u64(input, i));
            v2 = round(v2, read_u64(input, i + 8));
            v3 = round(v3, read_u64(input, i + 16));
            v4 = round(v4, read_u64(input, i + 24));
            i += 32;
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(PRIME64_5);
    }

    h = h.wrapping_add(len as u64);

    while i + 8 <= len {
        h ^= round(0, read_u64(input, i));
        h = h.rotate_left(27)
            .wrapping_mul(PRIME64_1)
            .wrapping_add(PRIME64_4);
        i += 8;
    }
    if i + 4 <= len {
        h ^= (read_u32(input, i) as u64).wrapping_mul(PRIME64_1);
        h = h.rotate_left(23)
            .wrapping_mul(PRIME64_2)
            .wrapping_add(PRIME64_3);
        i += 4;
    }
    while i < len {
        h ^= (input[i] as u64).wrapping_mul(PRIME64_5);
        h = h.rotate_left(11).wrapping_mul(PRIME64_1);
        i += 1;
    }
    avalanche(h)
}

/// xxHash64 specialised to a single little-endian `u64` key — the hot-path
/// hash. Identical to `xxhash64(&key.to_le_bytes(), seed)`.
#[inline(always)]
pub fn xxhash64_u64(key: u64, seed: u64) -> u64 {
    let mut h = seed.wrapping_add(PRIME64_5).wrapping_add(8);
    h ^= round(0, key);
    h = h.rotate_left(27)
        .wrapping_mul(PRIME64_1)
        .wrapping_add(PRIME64_4);
    avalanche(h)
}

/// Default seed used across the crate (and baked into the AOT artifacts).
pub const DEFAULT_SEED: u64 = 0x5EED_CAFE_F00D_D00D;

#[cfg(test)]
mod tests {
    use super::*;

    // Golden vectors produced with the reference xxHash implementation
    // (python xxhash / C xxh64). These pin Rust and Python to identical
    // bit-level behaviour.
    #[test]
    fn golden_empty() {
        assert_eq!(xxhash64(b"", 0), 0xEF46_DB37_51D8_E999);
    }

    #[test]
    fn golden_abc() {
        assert_eq!(xxhash64(b"abc", 0), 0x44BC_2CF5_AD77_0999);
    }

    #[test]
    fn golden_hello_seeded() {
        // xxh64("Hello, world!", seed=20141025)
        assert_eq!(xxhash64(b"Hello, world!", 20141025), 0x9409_FD3E_3AEE_7471);
    }

    #[test]
    fn golden_long_input() {
        // 64 bytes of 0..63 — exercises the 32-byte stripe loop.
        let data: Vec<u8> = (0u8..64).collect();
        assert_eq!(xxhash64(&data, 0), 0xF7C6_7301_DB67_13F0);
    }

    #[test]
    fn u64_fast_path_matches_bytes_path() {
        for (i, key) in [0u64, 1, 42, u64::MAX, 0xDEAD_BEEF_CAFE_BABE]
            .into_iter()
            .enumerate()
        {
            let seed = i as u64 * 0x1234_5678;
            assert_eq!(
                xxhash64_u64(key, seed),
                xxhash64(&key.to_le_bytes(), seed),
                "key={key:#x} seed={seed:#x}"
            );
        }
        // And a sweep.
        let mut s = crate::util::SplitMix64::new(99);
        for _ in 0..10_000 {
            let key = s.next_u64();
            assert_eq!(xxhash64_u64(key, DEFAULT_SEED), xxhash64(&key.to_le_bytes(), DEFAULT_SEED));
        }
    }

    #[test]
    fn distributes_bits() {
        // Sanity: low/high 32-bit halves of sequential keys look uniform.
        let n = 1 << 14;
        let mut buckets = vec![0u32; 64];
        for k in 0..n {
            let h = xxhash64_u64(k, DEFAULT_SEED);
            buckets[(h % 64) as usize] += 1;
        }
        let expect = n as f64 / 64.0;
        for &c in &buckets {
            assert!((c as f64) > expect * 0.7 && (c as f64) < expect * 1.3);
        }
    }
}
