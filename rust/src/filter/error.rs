//! Error types for the filter core. Hand-rolled `Display`/`Error` impls
//! keep the crate dependency-free (no `thiserror`).

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FilterError {
    /// Invalid configuration (validated at construction).
    BadConfig(String),

    /// Insertion abandoned after the eviction budget was exhausted —
    /// "Table too full, caller will have to rebuild" (Alg. 1).
    TooFull { evictions: usize },
}

impl fmt::Display for FilterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FilterError::BadConfig(msg) => write!(f, "bad filter configuration: {msg}"),
            FilterError::TooFull { evictions } => write!(
                f,
                "filter too full: eviction budget exhausted after {evictions} evictions"
            ),
        }
    }
}

impl std::error::Error for FilterError {}
