//! Error types for the filter core.

use thiserror::Error;

#[derive(Error, Debug, Clone, PartialEq, Eq)]
pub enum FilterError {
    /// Invalid configuration (validated at construction).
    #[error("bad filter configuration: {0}")]
    BadConfig(String),

    /// Insertion abandoned after the eviction budget was exhausted —
    /// "Table too full, caller will have to rebuild" (Alg. 1).
    #[error("filter too full: eviction budget exhausted after {evictions} evictions")]
    TooFull { evictions: usize },
}
