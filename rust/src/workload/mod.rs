//! Workload generation for the evaluation (§5.2): uniformly distributed
//! 64-bit integers, with the positive/negative split of §5.3 — inserted
//! keys drawn from [0, 2^32), negative probes from [2^32, 2^64) — so
//! probes are *guaranteed* absent and every positive probe is present.

use crate::util::prng::Xoshiro256;

/// Keys for insertion: uniform in [0, 2^32) (distinct with high
/// probability; the paper's FPR protocol uses this range).
pub fn insert_keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64() >> 32).collect()
}

/// Distinct keys for insertion (deduplicated uniform draw — used where
/// duplicate fingerprint copies would distort occupancy accounting).
pub fn distinct_insert_keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    let mut keys: Vec<u64> = Vec::with_capacity(n + n / 8);
    // Draw from the full 2^32 space then dedup; top up as needed.
    while keys.len() < n {
        keys.extend((0..(n - keys.len()) + 64).map(|_| rng.next_u64() >> 32));
        keys.sort_unstable();
        keys.dedup();
    }
    let mut rng2 = Xoshiro256::new(seed ^ 0xF00D);
    rng2.shuffle(&mut keys);
    keys.truncate(n);
    keys
}

/// Negative probes: uniform in [2^32, 2^64) — disjoint from insert keys.
pub fn negative_probes(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let v = rng.next_u64();
            if v < (1u64 << 32) {
                v | (1u64 << 32)
            } else {
                v
            }
        })
        .collect()
}

/// Positive probes: a shuffled resample of inserted keys.
pub fn positive_probes(inserted: &[u64], n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| inserted[rng.next_below(inserted.len() as u64) as usize])
        .collect()
}

/// Zipf-distributed probe workload (skewed access, used by the ablation
/// benches; s is the exponent, 0 = uniform).
pub fn zipf_probes(inserted: &[u64], n: usize, s: f64, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    let m = inserted.len();
    // Inverse-CDF sampling over a truncated zeta distribution.
    let norm: f64 = (1..=m).map(|i| 1.0 / (i as f64).powf(s)).sum();
    let mut cdf = Vec::with_capacity(m);
    let mut acc = 0.0;
    for i in 1..=m {
        acc += 1.0 / (i as f64).powf(s) / norm;
        cdf.push(acc);
    }
    (0..n)
        .map(|_| {
            let u = rng.next_f64();
            let idx = cdf.partition_point(|&c| c < u).min(m - 1);
            inserted[idx]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_disjoint() {
        let ins = insert_keys(10_000, 1);
        let neg = negative_probes(10_000, 2);
        assert!(ins.iter().all(|&k| k < (1 << 32)));
        assert!(neg.iter().all(|&k| k >= (1 << 32)));
    }

    #[test]
    fn distinct_keys_are_distinct() {
        let ks = distinct_insert_keys(50_000, 3);
        assert_eq!(ks.len(), 50_000);
        let mut sorted = ks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 50_000);
    }

    #[test]
    fn positive_probes_come_from_inserted() {
        let ins = insert_keys(1000, 4);
        let pos = positive_probes(&ins, 5000, 5);
        let set: std::collections::HashSet<u64> = ins.iter().cloned().collect();
        assert!(pos.iter().all(|k| set.contains(k)));
    }

    #[test]
    fn zipf_skews_head() {
        let ins: Vec<u64> = (0..1000).collect();
        let probes = zipf_probes(&ins, 20_000, 1.2, 6);
        let head_hits = probes.iter().filter(|&&k| k < 10).count();
        // With s=1.2 the top-10 items should get far more than 1% of hits.
        assert!(head_hits > 2_000, "head hits = {head_hits}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(insert_keys(100, 7), insert_keys(100, 7));
        assert_ne!(insert_keys(100, 7), insert_keys(100, 8));
    }
}
