//! Pooled batch-buffer arena: typed, capacity-retaining leases over
//! per-size-class free lists, so the serving stack's steady state puts
//! **zero** batch scratch on the global allocator.
//!
//! The paper's throughput argument is that a Cuckoo filter can saturate
//! memory bandwidth by embracing random access; the serving layers above
//! the kernel must therefore keep their own hot path equally lean. A
//! [`BufferArena`] holds one [`Pool`] per scratch element type the batch
//! pipeline needs (scatter pairs, index tables, outcome flags, tally
//! atomics, staged keys). [`Pool::lease`] hands out a [`Lease`] — a
//! cleared `Vec<T>` with at least the requested capacity — and dropping
//! the lease returns the buffer (capacity intact, elements dropped) to
//! the pool's free list for the next batch.
//!
//! ## Size classes and the hit/miss contract
//!
//! Free buffers are bucketed by the power of two at or below their
//! capacity; a lease request for `n` elements rounds up to the class
//! that guarantees capacity ≥ `n` and takes the first buffer found in
//! that class **or any larger one** (so a buffer that grew past its
//! original class — e.g. a batcher group that overflowed `max_keys` —
//! keeps getting reused instead of stranding). A satisfied request is a
//! *hit*; an empty scan allocates fresh (capacity rounded up to the
//! class size so the buffer re-enters its own class) and counts a
//! *miss*. After warmup a fixed workload must therefore run at a 100%
//! hit rate — `tests/alloc_reuse.rs` enforces exactly that, which is
//! how "steady-state zero-allocation" is a tested property rather than
//! a hope.
//!
//! ## Partitions (hardware-placement mode)
//!
//! [`BufferArena::partitioned`] splits every pool's free lists into `n`
//! independent partitions — the engine sizes `n` to the backend's
//! stream count — each with its own hit/miss/resident counters
//! ([`BufferArena::partition_stats`]). [`Pool::lease_in`] serves from
//! exactly one partition; the lease remembers its home
//! ([`Lease::home`]) and returns there on drop, so a fixed workload
//! holds *per-partition* misses constant, not just the aggregate.
//! Cross-partition traffic is explicit and counted: [`Lease::donate_to`]
//! tallies a donation that lands away from home
//! ([`BufferArena::cross_donations`]), while the provenance-free
//! [`Pool::donate`] always lands in partition 0 (the partition the
//! detached out-vector path leases from). [`BufferArena::new`] is
//! `partitioned(1)` — byte-identical to the historical single-free-list
//! arena.
//!
//! ## Lifecycle and ownership
//!
//! Leases are plain owned values (`Deref`/`DerefMut` to `Vec<T>`): they
//! may move across threads and return to the pool from wherever they are
//! dropped. Two escape hatches close the serving loop:
//!
//! * [`Lease::detach`] — take the `Vec` out of the lease *without*
//!   returning it to the pool (used when a buffer is handed to a caller,
//!   e.g. a response's outcome bits).
//! * [`Pool::donate`] — push any `Vec` into the matching free list
//!   (used by the batcher to recycle a response's outcome buffer after
//!   the per-client replies are scattered, so the next batch's out
//!   vector is a hit again).
//!
//! Who recycles *when* is a correctness question one layer up: the
//! sharded filter ties lease recycling to `BatchTicket` resolution so a
//! buffer can never return to the pool while a device kernel may still
//! read or write it (see `coordinator::shard`).
//!
//! Each free list is capped (`PER_CLASS_CAP` buffers per class and
//! partition); a return beyond the cap simply drops the buffer, bounding
//! resident memory under bursty workloads. [`BufferArena::stats`]
//! exposes the aggregate hit/miss/resident-bytes counters the server's
//! STATS reply reports.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One bucket per possible power-of-two capacity class.
const NUM_CLASSES: usize = usize::BITS as usize;

/// Free buffers retained per class (per partition); returns beyond this
/// are dropped so resident memory stays bounded.
const PER_CLASS_CAP: usize = 32;

/// Smallest class whose buffers are guaranteed to hold `n` elements.
fn class_for_request(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// The class a buffer of `cap > 0` belongs to (largest power of two at
/// or below `cap`, so membership implies capacity ≥ the class size).
fn class_for_capacity(cap: usize) -> usize {
    debug_assert!(cap > 0);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

/// Per-partition counters, shared by every pool of the arena.
#[derive(Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    resident_bytes: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ArenaStats {
        ArenaStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Arena-wide shared state: one counter set per partition plus the
/// cross-partition donation tally. Every typed pool of one arena holds
/// the same `ArenaShared`, so the aggregate counters tell the whole
/// story across scratch types.
struct ArenaShared {
    parts: Vec<Counters>,
    cross_donations: AtomicU64,
}

/// Point-in-time arena counters: lease requests served from a free list
/// (`hits`) vs freshly allocated (`misses`), and the bytes currently
/// parked in free lists (`resident_bytes`). A steady-state workload
/// holds `misses` constant — the observable form of "zero new scratch
/// allocations".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArenaStats {
    pub hits: u64,
    pub misses: u64,
    pub resident_bytes: u64,
}

impl ArenaStats {
    /// Total lease requests.
    pub fn acquires(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of requests served without allocating (1.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.acquires();
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

type FreeLists<T> = Vec<Vec<Vec<T>>>;

struct PoolInner<T> {
    /// One independent free-list set per partition.
    parts: Vec<Mutex<FreeLists<T>>>,
    shared: Arc<ArenaShared>,
}

impl<T> PoolInner<T> {
    /// Return a buffer to its capacity class in `part` (elements
    /// dropped, capacity kept). Zero-capacity and over-cap returns are
    /// silently dropped.
    fn put(&self, part: usize, mut buf: Vec<T>) {
        buf.clear();
        if buf.capacity() == 0 {
            return;
        }
        let class = class_for_capacity(buf.capacity());
        let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
        let mut classes = self.parts[part].lock().unwrap();
        if classes[class].len() >= PER_CLASS_CAP {
            return; // dropped: bounds resident memory under bursts
        }
        self.shared.parts[part].resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        classes[class].push(buf);
    }
}

/// A typed free-list pool of one arena (see the module docs).
pub struct Pool<T> {
    inner: Arc<PoolInner<T>>,
}

impl<T> Pool<T> {
    fn new(partitions: usize, shared: Arc<ArenaShared>) -> Self {
        Self {
            inner: Arc::new(PoolInner {
                parts: (0..partitions)
                    .map(|_| Mutex::new((0..NUM_CLASSES).map(|_| Vec::new()).collect()))
                    .collect(),
                shared,
            }),
        }
    }

    /// Lease a cleared buffer with capacity ≥ `min_capacity` from
    /// partition 0 — equivalent to [`Pool::lease_in`]`(0, ..)`, and the
    /// whole story on a single-partition arena.
    pub fn lease(&self, min_capacity: usize) -> Lease<T> {
        self.lease_in(0, min_capacity)
    }

    /// Lease a cleared buffer with capacity ≥ `min_capacity` from one
    /// partition's free lists. Served from the smallest adequate class
    /// with a free buffer **in that partition** (a *hit*, counted
    /// against that partition), else freshly allocated at the
    /// class-rounded capacity (a *miss*). The lease remembers
    /// `partition` as its home and returns there on drop.
    pub fn lease_in(&self, partition: usize, min_capacity: usize) -> Lease<T> {
        let class = class_for_request(min_capacity);
        let counters = &self.inner.shared.parts[partition];
        {
            let mut classes = self.inner.parts[partition].lock().unwrap();
            for bucket in classes[class..].iter_mut() {
                if let Some(buf) = bucket.pop() {
                    let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
                    counters.hits.fetch_add(1, Ordering::Relaxed);
                    counters.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
                    return Lease {
                        buf,
                        home: partition,
                        pool: Some(self.inner.clone()),
                    };
                }
            }
        }
        counters.misses.fetch_add(1, Ordering::Relaxed);
        let capacity = min_capacity.max(1).next_power_of_two();
        Lease {
            buf: Vec::with_capacity(capacity),
            home: partition,
            pool: Some(self.inner.clone()),
        }
    }

    /// Push an arbitrary `Vec` into the matching free list — the return
    /// half of [`Lease::detach`], used to recycle buffers that left the
    /// arena (e.g. response outcome vectors) once their consumer is
    /// done. Provenance is unknown by construction, so the buffer lands
    /// in partition 0 — the partition the detached-buffer paths lease
    /// from — and is never counted as a cross-partition donation.
    pub fn donate(&self, buf: Vec<T>) {
        self.inner.put(0, buf);
    }

    /// Drop every pooled buffer in every partition (counters other than
    /// resident bytes are preserved). Subsequent leases miss — the
    /// "fresh allocation" baseline the `scatter_reuse` bench compares
    /// against.
    pub fn clear(&self) {
        for (part, counters) in self.inner.parts.iter().zip(&self.inner.shared.parts) {
            let mut classes = part.lock().unwrap();
            for bucket in classes.iter_mut() {
                for buf in bucket.drain(..) {
                    let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
                    counters.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
                }
            }
        }
    }
}

/// A pooled buffer on loan: behaves as a `Vec<T>`, returns to its home
/// partition's free list (capacity intact) on drop. [`Lease::detach`]
/// opts out of the return; [`Lease::detached`] is an empty, pool-less
/// lease for paths that don't use a given buffer.
pub struct Lease<T> {
    buf: Vec<T>,
    home: usize,
    pool: Option<Arc<PoolInner<T>>>,
}

impl<T> Lease<T> {
    /// An empty lease bound to no pool (dropping it is a no-op and
    /// counts nothing).
    pub fn detached() -> Self {
        Self {
            buf: Vec::new(),
            home: 0,
            pool: None,
        }
    }

    /// The partition this lease was served from and returns to on drop
    /// (always 0 on a single-partition arena).
    pub fn home(&self) -> usize {
        self.home
    }

    /// Take the buffer out of the lease without returning it to the
    /// pool. Pair with [`Pool::donate`] to close the cycle later.
    pub fn detach(mut self) -> Vec<T> {
        self.pool = None;
        std::mem::take(&mut self.buf)
    }

    /// Return the buffer to `partition` instead of home. A target other
    /// than home is the one sanctioned way scratch migrates between
    /// partitions, and it is counted ([`BufferArena::cross_donations`])
    /// so placement drift shows up in STATS instead of silently eroding
    /// per-partition hit rates.
    pub fn donate_to(mut self, partition: usize) {
        if let Some(pool) = self.pool.take() {
            if partition != self.home {
                pool.shared.cross_donations.fetch_add(1, Ordering::Relaxed);
            }
            pool.put(partition, std::mem::take(&mut self.buf));
        }
    }
}

impl<T> Deref for Lease<T> {
    type Target = Vec<T>;

    fn deref(&self) -> &Vec<T> {
        &self.buf
    }
}

impl<T> DerefMut for Lease<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.buf
    }
}

impl<T> Drop for Lease<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put(self.home, std::mem::take(&mut self.buf));
        }
    }
}

/// The batch pipeline's shared scratch arena: one typed pool per
/// scratch shape the submit path leases (see the module docs). One
/// arena is shared by engine, batcher and sharded filter so every layer
/// recycles into the same free lists and the aggregate counters tell
/// the whole story.
pub struct BufferArena {
    shared: Arc<ArenaShared>,
    /// Round-robin cursor handing out home partitions to chunk scratch
    /// (see [`BufferArena::next_home`]).
    home_cursor: AtomicU64,
    pairs: Pool<(u64, u32)>,
    indices: Pool<usize>,
    flags: Pool<bool>,
    tallies: Pool<AtomicU64>,
    keys: Pool<u64>,
    bytes: Pool<u8>,
}

impl Default for BufferArena {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferArena {
    /// A single-partition arena — the historical default; every lease
    /// and donation lands in partition 0.
    pub fn new() -> Self {
        Self::partitioned(1)
    }

    /// An arena whose free lists are split into `partitions` independent
    /// sets (clamped to ≥ 1), one per backend stream, each with its own
    /// counters. See the module docs' "Partitions" section.
    pub fn partitioned(partitions: usize) -> Self {
        let n = partitions.max(1);
        let shared = Arc::new(ArenaShared {
            parts: (0..n).map(|_| Counters::default()).collect(),
            cross_donations: AtomicU64::new(0),
        });
        Self {
            pairs: Pool::new(n, shared.clone()),
            indices: Pool::new(n, shared.clone()),
            flags: Pool::new(n, shared.clone()),
            tallies: Pool::new(n, shared.clone()),
            keys: Pool::new(n, shared.clone()),
            bytes: Pool::new(n, shared.clone()),
            home_cursor: AtomicU64::new(0),
            shared,
        }
    }

    /// Number of free-list partitions (1 for [`BufferArena::new`]).
    pub fn partitions(&self) -> usize {
        self.shared.parts.len()
    }

    /// The next home partition for a batch's scratch, round-robin over
    /// the partitions (always 0 on a single-partition arena). The
    /// submit path calls this once per chunk so all of one chunk's
    /// scratch homes together and successive chunks cycle through the
    /// partitions deterministically.
    pub fn next_home(&self) -> usize {
        let n = self.shared.parts.len();
        if n <= 1 {
            return 0;
        }
        (self.home_cursor.fetch_add(1, Ordering::Relaxed) % n as u64) as usize
    }

    /// `(key, original index)` scatter pairs — the one flat batch buffer.
    pub fn pairs(&self) -> &Pool<(u64, u32)> {
        &self.pairs
    }

    /// Offset/cursor/segment-table indices.
    pub fn indices(&self) -> &Pool<usize> {
        &self.indices
    }

    /// Per-key outcome flags (the out vector / response outcomes).
    pub fn flags(&self) -> &Pool<bool> {
        &self.flags
    }

    /// Per-shard success tallies.
    pub fn tallies(&self) -> &Pool<AtomicU64> {
        &self.tallies
    }

    /// Staged key buffers (single-shard fast path, batcher groups).
    pub fn keys(&self) -> &Pool<u64> {
        &self.keys
    }

    /// Serialized-record staging (the write-ahead log's append path
    /// builds each flush group's record here, so WAL-enabled serving
    /// keeps the zero-allocation steady state; see
    /// `coordinator::wal`).
    pub fn bytes(&self) -> &Pool<u8> {
        &self.bytes
    }

    /// Aggregate counters across every pool and partition of this arena.
    pub fn stats(&self) -> ArenaStats {
        let mut total = ArenaStats {
            hits: 0,
            misses: 0,
            resident_bytes: 0,
        };
        for c in &self.shared.parts {
            let s = c.snapshot();
            total.hits += s.hits;
            total.misses += s.misses;
            total.resident_bytes += s.resident_bytes;
        }
        total
    }

    /// Per-partition counters, in partition order. On a partitioned
    /// arena a steady workload must hold *each entry's* misses constant
    /// — the per-partition form of the zero-allocation contract that
    /// `tests/alloc_reuse.rs` enforces.
    pub fn partition_stats(&self) -> Vec<ArenaStats> {
        self.shared.parts.iter().map(Counters::snapshot).collect()
    }

    /// Buffers returned to a partition other than their home via
    /// [`Lease::donate_to`] — the explicit cross-partition traffic
    /// counter STATS reports.
    pub fn cross_donations(&self) -> u64 {
        self.shared.cross_donations.load(Ordering::Relaxed)
    }

    /// Drop every pooled buffer in every pool (hit/miss history is
    /// preserved; resident bytes drop to zero).
    pub fn clear(&self) {
        self.pairs.clear();
        self.indices.clear();
        self.flags.clear();
        self.tallies.clear();
        self.keys.clear();
        self.bytes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding_guarantees_capacity() {
        assert_eq!(class_for_request(0), 0);
        assert_eq!(class_for_request(1), 0);
        assert_eq!(class_for_request(2), 1);
        assert_eq!(class_for_request(3), 2);
        assert_eq!(class_for_request(1024), 10);
        assert_eq!(class_for_request(1025), 11);
        assert_eq!(class_for_capacity(1), 0);
        assert_eq!(class_for_capacity(1024), 10);
        assert_eq!(class_for_capacity(1536), 10);
        // Membership invariant: any buffer in the class a request rounds
        // to has enough capacity for the request.
        for n in 1..=4096usize {
            assert!(1usize << class_for_request(n) >= n, "n={n}");
        }
    }

    #[test]
    fn lease_miss_then_hit_reuses_the_same_buffer() {
        let arena = BufferArena::new();
        let mut a = arena.keys().lease(1000);
        a.extend(0..1000u64);
        let ptr = a.as_ptr();
        assert_eq!(arena.stats().misses, 1);
        drop(a);
        assert!(arena.stats().resident_bytes >= 1000 * 8);

        let b = arena.keys().lease(900); // same class (1024)
        assert_eq!(b.as_ptr(), ptr, "free-listed buffer not reused");
        assert!(b.is_empty(), "leases arrive cleared");
        assert!(b.capacity() >= 1024);
        let s = arena.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn upward_search_reuses_grown_buffers() {
        let arena = BufferArena::new();
        let mut a = arena.keys().lease(100);
        // Outgrow the leased class (the batcher's join-overflow case).
        a.extend(0..5000u64);
        drop(a);
        // A class-7 request is served by the class-12 buffer upstairs.
        let b = arena.keys().lease(100);
        assert!(b.capacity() >= 5000);
        assert_eq!(arena.stats().hits, 1);
        assert_eq!(arena.stats().misses, 1);
    }

    #[test]
    fn detach_and_donate_close_the_cycle() {
        let arena = BufferArena::new();
        let mut l = arena.flags().lease(64);
        l.resize(64, true);
        let v = l.detach();
        assert_eq!(arena.stats().resident_bytes, 0, "detached buffers leave the arena");
        let ptr = v.as_ptr();
        arena.flags().donate(v);
        let back = arena.flags().lease(64);
        assert_eq!(back.as_ptr(), ptr);
        assert!(back.iter().all(|&b| !b) || back.is_empty(), "donated buffers are cleared");
        assert_eq!(arena.stats().hits, 1);
    }

    #[test]
    fn detached_lease_is_inert() {
        let l: Lease<u64> = Lease::detached();
        assert!(l.is_empty());
        assert_eq!(l.home(), 0);
        drop(l); // no pool, no counters, no panic
    }

    #[test]
    fn per_class_cap_bounds_resident_memory() {
        let arena = BufferArena::new();
        let leases: Vec<_> = (0..PER_CLASS_CAP + 8).map(|_| arena.keys().lease(64)).collect();
        drop(leases);
        let s = arena.stats();
        assert_eq!(s.misses as usize, PER_CLASS_CAP + 8);
        // Only PER_CLASS_CAP buffers were retained.
        assert_eq!(s.resident_bytes as usize, PER_CLASS_CAP * 64 * 8);
    }

    #[test]
    fn clear_resets_residency_but_not_history() {
        let arena = BufferArena::new();
        drop(arena.pairs().lease(256));
        drop(arena.indices().lease(256));
        assert!(arena.stats().resident_bytes > 0);
        arena.clear();
        let s = arena.stats();
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.misses, 2, "clear keeps the hit/miss history");
        // Next lease misses again — the fresh-alloc bench baseline.
        drop(arena.pairs().lease(256));
        assert_eq!(arena.stats().misses, 3);
    }

    #[test]
    fn leases_return_from_other_threads() {
        let arena = Arc::new(BufferArena::new());
        let lease = arena.keys().lease(512);
        let a = arena.clone();
        std::thread::spawn(move || drop(lease)).join().unwrap();
        assert_eq!(a.keys().lease(512).capacity(), 512);
        let s = a.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn tallies_pool_recycles_atomics() {
        let arena = BufferArena::new();
        let mut t = arena.tallies().lease(8);
        t.resize_with(8, || AtomicU64::new(7));
        drop(t);
        let mut t = arena.tallies().lease(8);
        assert!(t.is_empty(), "elements are dropped on return");
        t.resize_with(8, || AtomicU64::new(0));
        assert!(t.iter().all(|a| a.load(Ordering::Relaxed) == 0));
        assert_eq!(arena.stats().hits, 1);
    }

    #[test]
    fn single_partition_arena_is_the_partitioned_degenerate_case() {
        let arena = BufferArena::new();
        assert_eq!(arena.partitions(), 1);
        assert_eq!(arena.next_home(), 0);
        assert_eq!(arena.next_home(), 0, "single partition never advances");
        drop(arena.keys().lease(64));
        let parts = arena.partition_stats();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], arena.stats(), "one partition == the aggregate");
        assert_eq!(arena.cross_donations(), 0);
        // partitioned(0) clamps rather than building a zero-way arena.
        assert_eq!(BufferArena::partitioned(0).partitions(), 1);
    }

    #[test]
    fn partitioned_leases_stay_in_their_partition() {
        let arena = BufferArena::partitioned(2);
        let a = arena.keys().lease_in(1, 600);
        assert_eq!(a.home(), 1);
        drop(a); // returns to partition 1
        // Partition 0 cannot see partition 1's free buffer: fresh miss.
        let b = arena.keys().lease_in(0, 600);
        assert_eq!(b.home(), 0);
        drop(b);
        // Partition 1 reuses its own buffer: hit.
        let c = arena.keys().lease_in(1, 600);
        let parts = arena.partition_stats();
        assert_eq!((parts[0].hits, parts[0].misses), (0, 1));
        assert_eq!((parts[1].hits, parts[1].misses), (1, 1));
        let total = arena.stats();
        assert_eq!((total.hits, total.misses), (1, 2), "aggregate sums the partitions");
        drop(c);
    }

    #[test]
    fn cross_partition_donation_is_counted() {
        let arena = BufferArena::partitioned(2);
        // Home donation: no cross traffic.
        arena.flags().lease_in(1, 64).donate_to(1);
        assert_eq!(arena.cross_donations(), 0);
        // Away donation: counted, and the buffer really moves.
        arena.flags().lease_in(0, 64).donate_to(1);
        assert_eq!(arena.cross_donations(), 1);
        let hit = arena.flags().lease_in(1, 64);
        assert_eq!(arena.partition_stats()[1].hits, 1);
        drop(hit);
        // Pool::donate (provenance unknown) lands in partition 0, uncounted.
        let v = arena.keys().lease_in(1, 64).detach();
        arena.keys().donate(v);
        assert_eq!(arena.cross_donations(), 1);
        assert_eq!(arena.keys().lease_in(0, 64).capacity(), 64);
        assert_eq!(arena.partition_stats()[0].hits, 1);
    }

    #[test]
    fn next_home_round_robins_deterministically() {
        let arena = BufferArena::partitioned(3);
        let homes: Vec<usize> = (0..7).map(|_| arena.next_home()).collect();
        assert_eq!(homes, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn per_partition_misses_hold_constant_in_steady_state() {
        // The per-partition form of the zero-allocation contract: after
        // one warmup cycle over every partition, a repeating workload
        // adds hits only, to the partition it homes on.
        let arena = BufferArena::partitioned(4);
        for round in 0..8 {
            let home = arena.next_home();
            assert_eq!(home, round % 4);
            drop(arena.pairs().lease_in(home, 1024));
            drop(arena.indices().lease_in(home, 64));
        }
        for (i, p) in arena.partition_stats().iter().enumerate() {
            assert_eq!(p.misses, 2, "partition {i} warms up exactly once per pool/class");
            assert_eq!(p.hits, 2, "partition {i} reuses its own buffers thereafter");
        }
    }
}
