//! Pooled batch-buffer arena: typed, capacity-retaining leases over
//! per-size-class free lists, so the serving stack's steady state puts
//! **zero** batch scratch on the global allocator.
//!
//! The paper's throughput argument is that a Cuckoo filter can saturate
//! memory bandwidth by embracing random access; the serving layers above
//! the kernel must therefore keep their own hot path equally lean. A
//! [`BufferArena`] holds one [`Pool`] per scratch element type the batch
//! pipeline needs (scatter pairs, index tables, outcome flags, tally
//! atomics, staged keys). [`Pool::lease`] hands out a [`Lease`] — a
//! cleared `Vec<T>` with at least the requested capacity — and dropping
//! the lease returns the buffer (capacity intact, elements dropped) to
//! the pool's free list for the next batch.
//!
//! ## Size classes and the hit/miss contract
//!
//! Free buffers are bucketed by the power of two at or below their
//! capacity; a lease request for `n` elements rounds up to the class
//! that guarantees capacity ≥ `n` and takes the first buffer found in
//! that class **or any larger one** (so a buffer that grew past its
//! original class — e.g. a batcher group that overflowed `max_keys` —
//! keeps getting reused instead of stranding). A satisfied request is a
//! *hit*; an empty scan allocates fresh (capacity rounded up to the
//! class size so the buffer re-enters its own class) and counts a
//! *miss*. After warmup a fixed workload must therefore run at a 100%
//! hit rate — `tests/alloc_reuse.rs` enforces exactly that, which is
//! how "steady-state zero-allocation" is a tested property rather than
//! a hope.
//!
//! ## Lifecycle and ownership
//!
//! Leases are plain owned values (`Deref`/`DerefMut` to `Vec<T>`): they
//! may move across threads and return to the pool from wherever they are
//! dropped. Two escape hatches close the serving loop:
//!
//! * [`Lease::detach`] — take the `Vec` out of the lease *without*
//!   returning it to the pool (used when a buffer is handed to a caller,
//!   e.g. a response's outcome bits).
//! * [`Pool::donate`] — push any `Vec` into the matching free list
//!   (used by the batcher to recycle a response's outcome buffer after
//!   the per-client replies are scattered, so the next batch's out
//!   vector is a hit again).
//!
//! Who recycles *when* is a correctness question one layer up: the
//! sharded filter ties lease recycling to `BatchTicket` resolution so a
//! buffer can never return to the pool while a device kernel may still
//! read or write it (see `coordinator::shard`).
//!
//! Each free list is capped (`PER_CLASS_CAP` buffers per class); a
//! return beyond the cap simply drops the buffer, bounding resident
//! memory under bursty workloads. [`BufferArena::stats`] exposes the
//! aggregate hit/miss/resident-bytes counters the server's STATS reply
//! reports.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One bucket per possible power-of-two capacity class.
const NUM_CLASSES: usize = usize::BITS as usize;

/// Free buffers retained per class; returns beyond this are dropped so
/// resident memory stays bounded.
const PER_CLASS_CAP: usize = 32;

/// Smallest class whose buffers are guaranteed to hold `n` elements.
fn class_for_request(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// The class a buffer of `cap > 0` belongs to (largest power of two at
/// or below `cap`, so membership implies capacity ≥ the class size).
fn class_for_capacity(cap: usize) -> usize {
    debug_assert!(cap > 0);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

/// Arena-wide counters, shared by every pool of the arena.
#[derive(Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    resident_bytes: AtomicU64,
}

/// Point-in-time arena counters: lease requests served from a free list
/// (`hits`) vs freshly allocated (`misses`), and the bytes currently
/// parked in free lists (`resident_bytes`). A steady-state workload
/// holds `misses` constant — the observable form of "zero new scratch
/// allocations".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArenaStats {
    pub hits: u64,
    pub misses: u64,
    pub resident_bytes: u64,
}

impl ArenaStats {
    /// Total lease requests.
    pub fn acquires(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of requests served without allocating (1.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.acquires();
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

type FreeLists<T> = Vec<Vec<Vec<T>>>;

struct PoolInner<T> {
    classes: Mutex<FreeLists<T>>,
    counters: Arc<Counters>,
}

impl<T> PoolInner<T> {
    /// Return a buffer to its capacity class (elements dropped, capacity
    /// kept). Zero-capacity and over-cap returns are silently dropped.
    fn put(&self, mut buf: Vec<T>) {
        buf.clear();
        if buf.capacity() == 0 {
            return;
        }
        let class = class_for_capacity(buf.capacity());
        let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
        let mut classes = self.classes.lock().unwrap();
        if classes[class].len() >= PER_CLASS_CAP {
            return; // dropped: bounds resident memory under bursts
        }
        self.counters.resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        classes[class].push(buf);
    }
}

/// A typed free-list pool of one arena (see the module docs).
pub struct Pool<T> {
    inner: Arc<PoolInner<T>>,
}

impl<T> Pool<T> {
    fn new(counters: Arc<Counters>) -> Self {
        Self {
            inner: Arc::new(PoolInner {
                classes: Mutex::new((0..NUM_CLASSES).map(|_| Vec::new()).collect()),
                counters,
            }),
        }
    }

    /// Lease a cleared buffer with capacity ≥ `min_capacity`. Served
    /// from the smallest adequate class with a free buffer (a *hit*),
    /// else freshly allocated at the class-rounded capacity (a *miss*).
    pub fn lease(&self, min_capacity: usize) -> Lease<T> {
        let class = class_for_request(min_capacity);
        {
            let mut classes = self.inner.classes.lock().unwrap();
            for bucket in classes[class..].iter_mut() {
                if let Some(buf) = bucket.pop() {
                    let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
                    self.inner.counters.hits.fetch_add(1, Ordering::Relaxed);
                    self.inner.counters.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
                    return Lease {
                        buf,
                        pool: Some(self.inner.clone()),
                    };
                }
            }
        }
        self.inner.counters.misses.fetch_add(1, Ordering::Relaxed);
        let capacity = min_capacity.max(1).next_power_of_two();
        Lease {
            buf: Vec::with_capacity(capacity),
            pool: Some(self.inner.clone()),
        }
    }

    /// Push an arbitrary `Vec` into the matching free list — the return
    /// half of [`Lease::detach`], used to recycle buffers that left the
    /// arena (e.g. response outcome vectors) once their consumer is done.
    pub fn donate(&self, buf: Vec<T>) {
        self.inner.put(buf);
    }

    /// Drop every pooled buffer (counters other than resident bytes are
    /// preserved). Subsequent leases miss — the "fresh allocation"
    /// baseline the `scatter_reuse` bench compares against.
    pub fn clear(&self) {
        let mut classes = self.inner.classes.lock().unwrap();
        for bucket in classes.iter_mut() {
            for buf in bucket.drain(..) {
                let bytes = (buf.capacity() * std::mem::size_of::<T>()) as u64;
                self.inner.counters.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
            }
        }
    }
}

/// A pooled buffer on loan: behaves as a `Vec<T>`, returns to its free
/// list (capacity intact) on drop. [`Lease::detach`] opts out of the
/// return; [`Lease::detached`] is an empty, pool-less lease for paths
/// that don't use a given buffer.
pub struct Lease<T> {
    buf: Vec<T>,
    pool: Option<Arc<PoolInner<T>>>,
}

impl<T> Lease<T> {
    /// An empty lease bound to no pool (dropping it is a no-op and
    /// counts nothing).
    pub fn detached() -> Self {
        Self {
            buf: Vec::new(),
            pool: None,
        }
    }

    /// Take the buffer out of the lease without returning it to the
    /// pool. Pair with [`Pool::donate`] to close the cycle later.
    pub fn detach(mut self) -> Vec<T> {
        self.pool = None;
        std::mem::take(&mut self.buf)
    }
}

impl<T> Deref for Lease<T> {
    type Target = Vec<T>;

    fn deref(&self) -> &Vec<T> {
        &self.buf
    }
}

impl<T> DerefMut for Lease<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.buf
    }
}

impl<T> Drop for Lease<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put(std::mem::take(&mut self.buf));
        }
    }
}

/// The batch pipeline's shared scratch arena: one typed pool per
/// scratch shape the submit path leases (see the module docs). One
/// arena is shared by engine, batcher and sharded filter so every layer
/// recycles into the same free lists and the aggregate counters tell
/// the whole story.
pub struct BufferArena {
    counters: Arc<Counters>,
    pairs: Pool<(u64, u32)>,
    indices: Pool<usize>,
    flags: Pool<bool>,
    tallies: Pool<AtomicU64>,
    keys: Pool<u64>,
    bytes: Pool<u8>,
}

impl Default for BufferArena {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferArena {
    pub fn new() -> Self {
        let counters = Arc::new(Counters::default());
        Self {
            pairs: Pool::new(counters.clone()),
            indices: Pool::new(counters.clone()),
            flags: Pool::new(counters.clone()),
            tallies: Pool::new(counters.clone()),
            keys: Pool::new(counters.clone()),
            bytes: Pool::new(counters.clone()),
            counters,
        }
    }

    /// `(key, original index)` scatter pairs — the one flat batch buffer.
    pub fn pairs(&self) -> &Pool<(u64, u32)> {
        &self.pairs
    }

    /// Offset/cursor/segment-table indices.
    pub fn indices(&self) -> &Pool<usize> {
        &self.indices
    }

    /// Per-key outcome flags (the out vector / response outcomes).
    pub fn flags(&self) -> &Pool<bool> {
        &self.flags
    }

    /// Per-shard success tallies.
    pub fn tallies(&self) -> &Pool<AtomicU64> {
        &self.tallies
    }

    /// Staged key buffers (single-shard fast path, batcher groups).
    pub fn keys(&self) -> &Pool<u64> {
        &self.keys
    }

    /// Serialized-record staging (the write-ahead log's append path
    /// builds each flush group's record here, so WAL-enabled serving
    /// keeps the zero-allocation steady state; see
    /// `coordinator::wal`).
    pub fn bytes(&self) -> &Pool<u8> {
        &self.bytes
    }

    /// Aggregate counters across every pool of this arena.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.counters.hits.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
            resident_bytes: self.counters.resident_bytes.load(Ordering::Relaxed),
        }
    }

    /// Drop every pooled buffer in every pool (hit/miss history is
    /// preserved; resident bytes drop to zero).
    pub fn clear(&self) {
        self.pairs.clear();
        self.indices.clear();
        self.flags.clear();
        self.tallies.clear();
        self.keys.clear();
        self.bytes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding_guarantees_capacity() {
        assert_eq!(class_for_request(0), 0);
        assert_eq!(class_for_request(1), 0);
        assert_eq!(class_for_request(2), 1);
        assert_eq!(class_for_request(3), 2);
        assert_eq!(class_for_request(1024), 10);
        assert_eq!(class_for_request(1025), 11);
        assert_eq!(class_for_capacity(1), 0);
        assert_eq!(class_for_capacity(1024), 10);
        assert_eq!(class_for_capacity(1536), 10);
        // Membership invariant: any buffer in the class a request rounds
        // to has enough capacity for the request.
        for n in 1..=4096usize {
            assert!(1usize << class_for_request(n) >= n, "n={n}");
        }
    }

    #[test]
    fn lease_miss_then_hit_reuses_the_same_buffer() {
        let arena = BufferArena::new();
        let mut a = arena.keys().lease(1000);
        a.extend(0..1000u64);
        let ptr = a.as_ptr();
        assert_eq!(arena.stats().misses, 1);
        drop(a);
        assert!(arena.stats().resident_bytes >= 1000 * 8);

        let b = arena.keys().lease(900); // same class (1024)
        assert_eq!(b.as_ptr(), ptr, "free-listed buffer not reused");
        assert!(b.is_empty(), "leases arrive cleared");
        assert!(b.capacity() >= 1024);
        let s = arena.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn upward_search_reuses_grown_buffers() {
        let arena = BufferArena::new();
        let mut a = arena.keys().lease(100);
        // Outgrow the leased class (the batcher's join-overflow case).
        a.extend(0..5000u64);
        drop(a);
        // A class-7 request is served by the class-12 buffer upstairs.
        let b = arena.keys().lease(100);
        assert!(b.capacity() >= 5000);
        assert_eq!(arena.stats().hits, 1);
        assert_eq!(arena.stats().misses, 1);
    }

    #[test]
    fn detach_and_donate_close_the_cycle() {
        let arena = BufferArena::new();
        let mut l = arena.flags().lease(64);
        l.resize(64, true);
        let v = l.detach();
        assert_eq!(arena.stats().resident_bytes, 0, "detached buffers leave the arena");
        let ptr = v.as_ptr();
        arena.flags().donate(v);
        let back = arena.flags().lease(64);
        assert_eq!(back.as_ptr(), ptr);
        assert!(back.iter().all(|&b| !b) || back.is_empty(), "donated buffers are cleared");
        assert_eq!(arena.stats().hits, 1);
    }

    #[test]
    fn detached_lease_is_inert() {
        let l: Lease<u64> = Lease::detached();
        assert!(l.is_empty());
        drop(l); // no pool, no counters, no panic
    }

    #[test]
    fn per_class_cap_bounds_resident_memory() {
        let arena = BufferArena::new();
        let leases: Vec<_> = (0..PER_CLASS_CAP + 8).map(|_| arena.keys().lease(64)).collect();
        drop(leases);
        let s = arena.stats();
        assert_eq!(s.misses as usize, PER_CLASS_CAP + 8);
        // Only PER_CLASS_CAP buffers were retained.
        assert_eq!(s.resident_bytes as usize, PER_CLASS_CAP * 64 * 8);
    }

    #[test]
    fn clear_resets_residency_but_not_history() {
        let arena = BufferArena::new();
        drop(arena.pairs().lease(256));
        drop(arena.indices().lease(256));
        assert!(arena.stats().resident_bytes > 0);
        arena.clear();
        let s = arena.stats();
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.misses, 2, "clear keeps the hit/miss history");
        // Next lease misses again — the fresh-alloc bench baseline.
        drop(arena.pairs().lease(256));
        assert_eq!(arena.stats().misses, 3);
    }

    #[test]
    fn leases_return_from_other_threads() {
        let arena = Arc::new(BufferArena::new());
        let lease = arena.keys().lease(512);
        let a = arena.clone();
        std::thread::spawn(move || drop(lease)).join().unwrap();
        assert_eq!(a.keys().lease(512).capacity(), 512);
        let s = a.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn tallies_pool_recycles_atomics() {
        let arena = BufferArena::new();
        let mut t = arena.tallies().lease(8);
        t.resize_with(8, || AtomicU64::new(7));
        drop(t);
        let mut t = arena.tallies().lease(8);
        assert!(t.is_empty(), "elements are dropped on return");
        t.resize_with(8, || AtomicU64::new(0));
        assert!(t.iter().all(|a| a.load(Ordering::Relaxed) == 0));
        assert_eq!(arena.stats().hits, 1);
    }
}
