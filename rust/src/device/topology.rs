//! Multi-pool device topology: N independent persistent worker pools
//! with a stable shard → pool assignment.
//!
//! One [`super::Device`] is the CPU analogue of a single GPU: one FIFO
//! stream, one set of SMs. A [`DeviceTopology`] is the level above — the
//! multi-GPU (or NUMA-node) box. Each pool owns its own worker threads
//! and its own job queue, so fused kernels submitted to *different*
//! pools genuinely overlap instead of serialising behind one stream;
//! kernels submitted to the *same* pool keep the FIFO stream order that
//! the async batch pipeline relies on.
//!
//! The assignment is per **shard group**: every shard of a
//! `ShardedFilter` maps to exactly one pool ([`DeviceTopology::pool_for_shard`]),
//! either round-robin or via an explicit pinning table
//! ([`Pinning::Explicit`], the hook for real NUMA placement). Because the
//! mapping is stable, all operations touching one shard land on one
//! pool, and that pool's FIFO queue serialises the shard's mutation
//! batches in submission order — the cross-pool analogue of the
//! single-stream ordering guarantee.
//!
//! Worker budget: [`TopologyConfig::total_workers`] is divided across
//! pools (earlier pools take the remainder), so `pools = N` re-partitions
//! a fixed set of "SMs" instead of multiplying threads — the
//! fixed-hardware comparison the `topology_scaling` bench runs.
//!
//! Hardware placement ([`TopologyConfig::placement`]): a non-`None`
//! [`PlacementPolicy`] probes the socket topology once, computes one
//! target core per worker (`Compact` keeps each pool on one socket,
//! `Spread` interleaves sockets), and each pool's workers pin
//! themselves at spawn (see the `device` module docs). Under `Compact`
//! on a multi-socket machine a default round-robin shard map is
//! upgraded to a socket-major [`Pinning::Explicit`] map, so consecutive
//! shard groups fill one socket's pools before crossing to the next —
//! an explicitly-configured `Pinning` is never overridden. Placement
//! changes *where* work runs, never *what* it computes.

use super::{default_workers, Device, LaunchConfig};
use crate::util::affinity::{CpuTopology, PlacementPlan, PlacementPolicy};

/// Shard → pool assignment policy.
#[derive(Clone, Debug)]
pub enum Pinning {
    /// Shard `s` runs on pool `s % pools`.
    RoundRobin,
    /// Shard `s` runs on pool `map[s % map.len()] % pools` — an explicit
    /// placement table (the NUMA-pinning hook). An empty table falls
    /// back to round-robin.
    Explicit(Vec<usize>),
}

/// Geometry of a multi-pool topology.
#[derive(Clone, Debug)]
pub struct TopologyConfig {
    /// Number of independent device pools. Clamped to `total_workers`:
    /// a topology re-partitions a fixed worker budget, it never
    /// multiplies it.
    pub pools: usize,
    /// Worker threads divided across all pools (earlier pools absorb
    /// the remainder; the per-pool sum is exactly this budget).
    pub total_workers: usize,
    /// Per-pool launch geometry (see [`LaunchConfig`]).
    pub block_size: usize,
    pub warp_size: usize,
    pub pinning: Pinning,
    /// Worker→core placement. `PlacementPolicy::None` (the default) is
    /// inert: no probe, no syscalls, byte-identical to the pre-placement
    /// behavior.
    pub placement: PlacementPolicy,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        let lc = LaunchConfig::default();
        Self {
            pools: 1,
            total_workers: default_workers(),
            block_size: lc.block_size,
            warp_size: lc.warp_size,
            pinning: Pinning::RoundRobin,
            placement: PlacementPolicy::None,
        }
    }
}

/// N independent device pools plus the shard → pool assignment.
pub struct DeviceTopology {
    pools: Vec<Device>,
    pinning: Pinning,
    /// Placement label this topology was built under (STATS reporting).
    policy: &'static str,
}

impl DeviceTopology {
    pub fn new(cfg: TopologyConfig) -> Self {
        let total = cfg.total_workers.max(1);
        // Never oversubscribe: more pools than workers would silently
        // spawn threads beyond the configured budget, so the pool count
        // clamps to it and the per-pool sum is always exactly `total`.
        let n = cfg.pools.clamp(1, total);
        let base = total / n;
        let rem = total % n;
        let widths: Vec<usize> = (0..n).map(|i| base + usize::from(i < rem)).collect();
        // Placement: probe the socket layout once, derive one target
        // core per worker, and (Compact, >1 socket, default pinning
        // only) a socket-major shard map aligning shard groups with
        // sockets. `None` skips all of it.
        let policy = cfg.placement.label();
        let (plan, socket_order) = if cfg.placement.is_none() {
            (PlacementPlan::unpinned(n), None)
        } else {
            let topo = CpuTopology::probe();
            (cfg.placement.plan_on(&topo, &widths), cfg.placement.socket_pool_order(&topo, n))
        };
        let pinning = match (matches!(cfg.pinning, Pinning::RoundRobin), socket_order) {
            (true, Some(order)) => Pinning::Explicit(order),
            _ => cfg.pinning,
        };
        let pools = widths
            .iter()
            .zip(plan.pools)
            .map(|(&workers, cpus)| {
                Device::with_placement(
                    LaunchConfig {
                        block_size: cfg.block_size,
                        warp_size: cfg.warp_size,
                        workers,
                    },
                    cpus,
                    policy,
                )
            })
            .collect();
        Self {
            pools,
            pinning,
            policy,
        }
    }

    /// `pools` equal pools splitting `total_workers` round-robin.
    pub fn with_pools(pools: usize, total_workers: usize) -> Self {
        Self::new(TopologyConfig {
            pools,
            total_workers,
            ..TopologyConfig::default()
        })
    }

    /// Wrap one existing device as a single-pool topology.
    pub fn single(device: Device) -> Self {
        let policy = device.pin_policy();
        Self {
            pools: vec![device],
            pinning: Pinning::RoundRobin,
            policy,
        }
    }

    /// The placement label this topology was built under.
    pub fn policy(&self) -> &'static str {
        self.policy
    }

    pub fn num_pools(&self) -> usize {
        self.pools.len()
    }

    pub fn pool(&self, i: usize) -> &Device {
        &self.pools[i]
    }

    /// All pools, in pool-index order.
    pub fn pools(&self) -> &[Device] {
        &self.pools
    }

    /// The pool that owns shard `shard`. Stable for the topology's
    /// lifetime: all batches touching one shard serialise on one pool's
    /// FIFO queue.
    pub fn pool_for_shard(&self, shard: usize) -> usize {
        let n = self.pools.len();
        match &self.pinning {
            Pinning::Explicit(map) if !map.is_empty() => map[shard % map.len()] % n,
            _ => shard % n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_split_across_pools_with_remainder() {
        let t = DeviceTopology::new(TopologyConfig {
            pools: 3,
            total_workers: 7,
            ..TopologyConfig::default()
        });
        assert_eq!(t.num_pools(), 3);
        let w: Vec<usize> = t.pools().iter().map(|d| d.workers()).collect();
        assert_eq!(w, vec![3, 2, 2]);
        assert_eq!(w.iter().sum::<usize>(), 7);
    }

    #[test]
    fn pool_count_clamps_to_the_worker_budget() {
        // 4 pools over 2 workers would oversubscribe the budget; the
        // topology clamps to 2 pools of 1 worker each instead.
        let t = DeviceTopology::with_pools(4, 2);
        assert_eq!(t.num_pools(), 2);
        assert!(t.pools().iter().all(|d| d.workers() == 1));
        let total: usize = t.pools().iter().map(|d| d.workers()).sum();
        assert_eq!(total, 2, "budget re-partitioned, never multiplied");
    }

    #[test]
    fn round_robin_and_explicit_pinning() {
        let t = DeviceTopology::with_pools(2, 4);
        assert_eq!(t.pool_for_shard(0), 0);
        assert_eq!(t.pool_for_shard(1), 1);
        assert_eq!(t.pool_for_shard(2), 0);

        let t = DeviceTopology::new(TopologyConfig {
            pools: 2,
            total_workers: 4,
            pinning: Pinning::Explicit(vec![1, 1, 0]),
            ..TopologyConfig::default()
        });
        assert_eq!(t.pool_for_shard(0), 1);
        assert_eq!(t.pool_for_shard(1), 1);
        assert_eq!(t.pool_for_shard(2), 0);
        assert_eq!(t.pool_for_shard(3), 1); // wraps: map[3 % 3]
    }

    #[test]
    fn placement_threads_through_to_every_pool() {
        let t = DeviceTopology::new(TopologyConfig {
            pools: 2,
            total_workers: 4,
            placement: PlacementPolicy::Compact,
            ..TopologyConfig::default()
        });
        assert_eq!(t.policy(), "compact");
        for d in t.pools() {
            let (cpus, ok, failed) = d.pin_outcomes();
            assert_eq!(cpus.len(), d.workers(), "one target core per worker");
            assert_eq!(ok + failed, d.workers() as u64, "every outcome recorded");
        }
        // Placement never changes results.
        assert_eq!(t.pool(0).launch_items(10_000, |i| i % 2 == 0), 5_000);
        // The default stays inert: no targets, no attempts, no probe.
        let unpinned = DeviceTopology::with_pools(2, 4);
        assert_eq!(unpinned.policy(), "none");
        for d in unpinned.pools() {
            assert_eq!(d.pin_outcomes(), (Vec::new(), 0, 0));
        }
    }

    #[test]
    fn explicit_pinning_survives_placement_and_round_robin_upgrades_only_on_multi_socket() {
        // An explicitly-configured shard map must never be overridden by
        // placement, whatever the machine's socket count.
        let t = DeviceTopology::new(TopologyConfig {
            pools: 2,
            total_workers: 4,
            pinning: Pinning::Explicit(vec![1]),
            placement: PlacementPolicy::Compact,
            ..TopologyConfig::default()
        });
        assert_eq!(t.pool_for_shard(0), 1);
        assert_eq!(t.pool_for_shard(7), 1);
    }

    #[test]
    fn pools_run_independent_launches() {
        let t = DeviceTopology::with_pools(2, 4);
        let a = t.pool(0).launch_async(8_192, |ctx| {
            for _ in ctx.range.clone() {
                ctx.tally(true);
            }
        });
        let b = t.pool(1).launch_async(4_096, |ctx| {
            for _ in ctx.range.clone() {
                ctx.tally(true);
            }
        });
        // Waited out of order across pools.
        assert_eq!(b.wait(), 4_096);
        assert_eq!(a.wait(), 8_192);
        assert!(t.pool(0).launches() >= 1);
        assert!(t.pool(1).launches() >= 1);
    }
}
