//! Multi-pool device topology: N independent persistent worker pools
//! with a stable shard → pool assignment.
//!
//! One [`super::Device`] is the CPU analogue of a single GPU: one FIFO
//! stream, one set of SMs. A [`DeviceTopology`] is the level above — the
//! multi-GPU (or NUMA-node) box. Each pool owns its own worker threads
//! and its own job queue, so fused kernels submitted to *different*
//! pools genuinely overlap instead of serialising behind one stream;
//! kernels submitted to the *same* pool keep the FIFO stream order that
//! the async batch pipeline relies on.
//!
//! The assignment is per **shard group**: every shard of a
//! `ShardedFilter` maps to exactly one pool ([`DeviceTopology::pool_for_shard`]),
//! either round-robin or via an explicit pinning table
//! ([`Pinning::Explicit`], the hook for real NUMA placement). Because the
//! mapping is stable, all operations touching one shard land on one
//! pool, and that pool's FIFO queue serialises the shard's mutation
//! batches in submission order — the cross-pool analogue of the
//! single-stream ordering guarantee.
//!
//! Worker budget: [`TopologyConfig::total_workers`] is divided across
//! pools (earlier pools take the remainder), so `pools = N` re-partitions
//! a fixed set of "SMs" instead of multiplying threads — the
//! fixed-hardware comparison the `topology_scaling` bench runs.

use super::{default_workers, Device, LaunchConfig};

/// Shard → pool assignment policy.
#[derive(Clone, Debug)]
pub enum Pinning {
    /// Shard `s` runs on pool `s % pools`.
    RoundRobin,
    /// Shard `s` runs on pool `map[s % map.len()] % pools` — an explicit
    /// placement table (the NUMA-pinning hook). An empty table falls
    /// back to round-robin.
    Explicit(Vec<usize>),
}

/// Geometry of a multi-pool topology.
#[derive(Clone, Debug)]
pub struct TopologyConfig {
    /// Number of independent device pools. Clamped to `total_workers`:
    /// a topology re-partitions a fixed worker budget, it never
    /// multiplies it.
    pub pools: usize,
    /// Worker threads divided across all pools (earlier pools absorb
    /// the remainder; the per-pool sum is exactly this budget).
    pub total_workers: usize,
    /// Per-pool launch geometry (see [`LaunchConfig`]).
    pub block_size: usize,
    pub warp_size: usize,
    pub pinning: Pinning,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        let lc = LaunchConfig::default();
        Self {
            pools: 1,
            total_workers: default_workers(),
            block_size: lc.block_size,
            warp_size: lc.warp_size,
            pinning: Pinning::RoundRobin,
        }
    }
}

/// N independent device pools plus the shard → pool assignment.
pub struct DeviceTopology {
    pools: Vec<Device>,
    pinning: Pinning,
}

impl DeviceTopology {
    pub fn new(cfg: TopologyConfig) -> Self {
        let total = cfg.total_workers.max(1);
        // Never oversubscribe: more pools than workers would silently
        // spawn threads beyond the configured budget, so the pool count
        // clamps to it and the per-pool sum is always exactly `total`.
        let n = cfg.pools.clamp(1, total);
        let base = total / n;
        let rem = total % n;
        let pools = (0..n)
            .map(|i| {
                let workers = base + usize::from(i < rem);
                Device::new(LaunchConfig {
                    block_size: cfg.block_size,
                    warp_size: cfg.warp_size,
                    workers,
                })
            })
            .collect();
        Self {
            pools,
            pinning: cfg.pinning,
        }
    }

    /// `pools` equal pools splitting `total_workers` round-robin.
    pub fn with_pools(pools: usize, total_workers: usize) -> Self {
        Self::new(TopologyConfig {
            pools,
            total_workers,
            ..TopologyConfig::default()
        })
    }

    /// Wrap one existing device as a single-pool topology.
    pub fn single(device: Device) -> Self {
        Self {
            pools: vec![device],
            pinning: Pinning::RoundRobin,
        }
    }

    pub fn num_pools(&self) -> usize {
        self.pools.len()
    }

    pub fn pool(&self, i: usize) -> &Device {
        &self.pools[i]
    }

    /// All pools, in pool-index order.
    pub fn pools(&self) -> &[Device] {
        &self.pools
    }

    /// The pool that owns shard `shard`. Stable for the topology's
    /// lifetime: all batches touching one shard serialise on one pool's
    /// FIFO queue.
    pub fn pool_for_shard(&self, shard: usize) -> usize {
        let n = self.pools.len();
        match &self.pinning {
            Pinning::Explicit(map) if !map.is_empty() => map[shard % map.len()] % n,
            _ => shard % n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_split_across_pools_with_remainder() {
        let t = DeviceTopology::new(TopologyConfig {
            pools: 3,
            total_workers: 7,
            ..TopologyConfig::default()
        });
        assert_eq!(t.num_pools(), 3);
        let w: Vec<usize> = t.pools().iter().map(|d| d.workers()).collect();
        assert_eq!(w, vec![3, 2, 2]);
        assert_eq!(w.iter().sum::<usize>(), 7);
    }

    #[test]
    fn pool_count_clamps_to_the_worker_budget() {
        // 4 pools over 2 workers would oversubscribe the budget; the
        // topology clamps to 2 pools of 1 worker each instead.
        let t = DeviceTopology::with_pools(4, 2);
        assert_eq!(t.num_pools(), 2);
        assert!(t.pools().iter().all(|d| d.workers() == 1));
        let total: usize = t.pools().iter().map(|d| d.workers()).sum();
        assert_eq!(total, 2, "budget re-partitioned, never multiplied");
    }

    #[test]
    fn round_robin_and_explicit_pinning() {
        let t = DeviceTopology::with_pools(2, 4);
        assert_eq!(t.pool_for_shard(0), 0);
        assert_eq!(t.pool_for_shard(1), 1);
        assert_eq!(t.pool_for_shard(2), 0);

        let t = DeviceTopology::new(TopologyConfig {
            pools: 2,
            total_workers: 4,
            pinning: Pinning::Explicit(vec![1, 1, 0]),
            ..TopologyConfig::default()
        });
        assert_eq!(t.pool_for_shard(0), 1);
        assert_eq!(t.pool_for_shard(1), 1);
        assert_eq!(t.pool_for_shard(2), 0);
        assert_eq!(t.pool_for_shard(3), 1); // wraps: map[3 % 3]
    }

    #[test]
    fn pools_run_independent_launches() {
        let t = DeviceTopology::with_pools(2, 4);
        let a = t.pool(0).launch_async(8_192, |ctx| {
            for _ in ctx.range.clone() {
                ctx.tally(true);
            }
        });
        let b = t.pool(1).launch_async(4_096, |ctx| {
            for _ in ctx.range.clone() {
                ctx.tally(true);
            }
        });
        // Waited out of order across pools.
        assert_eq!(b.wait(), 4_096);
        assert_eq!(a.wait(), 8_192);
        assert!(t.pool(0).launches() >= 1);
        assert!(t.pool(1).launches() >= 1);
    }
}
