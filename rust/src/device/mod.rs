//! The batch "kernel launch" engine — the CPU stand-in for the CUDA
//! device (§2.2 / §4.3), built around a **persistent worker pool**.
//!
//! A [`Device`] owns a logical worker topology shaped like a GPU grid:
//! a batch of N items is decomposed into *blocks* of `block_size`
//! logical threads, blocks are distributed over OS worker threads
//! (the "SMs"), and inside a block, per-*warp* partial results are
//! reduced before a single atomic commit per block — the paper's
//! hierarchical occupancy counting (warp shuffle → shared memory →
//! one global atomic, §4.3 last paragraph).
//!
//! ## Execution model: launch = enqueue, not spawn
//!
//! Worker threads are spawned **exactly once**, when the [`Device`] is
//! constructed — the analogue of initialising the GPU and its SMs at
//! context creation. Submitting work does *not* create threads; it
//! pushes a type-erased kernel task onto a FIFO job queue (the single
//! CUDA stream), wakes the parked workers, and hands back a per-job
//! completion handle. Workers pull blocks from an atomic block cursor
//! (the hardware block scheduler) and retire jobs strictly in
//! submission order.
//!
//! Two submission surfaces share that queue:
//!
//! * [`Device::launch`] — the **synchronous** barrier launch: submit,
//!   then park on the job's completion (kernel + stream synchronise).
//!   Per-launch cost is a condvar wakeup (~µs), not a round of OS
//!   thread spawns (~tens of µs × workers). Launches whose grid fits a
//!   single block (or a single-worker pool) bypass the queue and run
//!   inline on the caller thread — but only while the pool is **idle**;
//!   with jobs in flight even a tiny launch queues behind them, so FIFO
//!   stream order holds for any single submitter. (Launches racing from
//!   different threads have no relative order, as with any one stream
//!   fed by many threads.)
//! * [`Device::launch_async`] — the **stream-ordered** launch: submit
//!   and return a [`LaunchToken`] immediately, without any barrier.
//!   Multiple async jobs may be in flight at once; they run FIFO and
//!   each token completes independently (condvar per job, no shared
//!   barrier). This is what lets the serving batcher overlap the
//!   scatter/permute of batch *k+1* on its own thread with the kernel
//!   of batch *k* on the pool — the cheap overlappable launches the
//!   paper's throughput model assumes.
//!
//! ## Token lifecycle
//!
//! [`LaunchToken::wait`] blocks until the job retires and returns the
//! hierarchical success count. Tokens may be waited **out of order**
//! (completion is per-job); a token that is dropped without `wait` is
//! fine — the job still runs to completion and its owned task state is
//! freed when it retires. A panic inside an async kernel is captured
//! and re-raised at `wait()` (never at submit), and the pool stays
//! serviceable afterwards. On `Device` drop, queued jobs are drained
//! before the workers exit, so every outstanding token completes.
//!
//! Kernels must not block on work submitted to their own device
//! (`launch` or `LaunchToken::wait` from inside a kernel) — that
//! self-deadlocks, exactly like a device-side sync inside a CUDA
//! kernel. Fire-and-forget `launch_async` from inside a kernel is
//! harmless but unordered with respect to the enclosing job.
//!
//! Borrow safety: a synchronous launch publishes a reference to the
//! caller's stack closure to 'static worker threads. The submitter
//! parks on that job's completion before returning, which retires the
//! borrow — the same contract scoped threads enforce structurally; the
//! lifetime erasure is confined to `Device::run_job`. Async launches
//! own their task state (`Arc`), so no lifetime erasure is involved.
//!
//! ## One device vs a topology of devices vs *any* backend
//!
//! A single `Device` is one GPU: one FIFO stream, one pool of SMs —
//! every launch submitted to it serialises behind the queue. The level
//! above is [`DeviceTopology`] (see [`topology`]): N independent pools
//! with a stable shard → pool assignment, so fused batches split into
//! per-pool segments and run concurrently across pools while each
//! pool's own stream order is preserved. Observability for that layer
//! lives here: [`Device::launches`] counts every non-empty launch
//! (inline fast paths included, unlike [`Device::pool_jobs`]) and
//! [`Device::queue_depth`] reports the submitted-but-unretired job
//! count — the per-stream counters `coordinator::metrics` reports.
//!
//! Both shapes sit behind **one** execution-layer surface, the
//! [`Backend`] trait (see [`backend`]): `streams()` submission streams,
//! `stream_for_shard()` placement, stream-ordered `submit()` returning
//! the same [`LaunchToken`] either way, and `stream_stats()`
//! introspection. `ShardedFilter`, `Engine` and the benches are written
//! against `&dyn Backend` / `&B: Backend` — a future real-GPU or PJRT
//! backend slots in as one more `impl`, not another set of batch paths.
//!
//! ## Hardware placement
//!
//! By default the OS scheduler places worker threads freely. A
//! [`PlacementPolicy`] (from [`crate::util::affinity`], re-exported
//! here) opts a backend into **core pinning**:
//! [`build_backend_placed`] probes the socket topology, computes one
//! target core per worker, and each pool's workers pin themselves **at
//! spawn** — in the worker prologue, before the first job — because
//! `sched_setaffinity` only targets the calling thread, and re-pinning
//! mid-stream would migrate a worker exactly when its cached filter
//! state is hottest (the cost pinning exists to avoid). Construction
//! waits for every worker to record its pin outcome, so the per-pool
//! ok/failed tallies in [`Backend::placement`] are settled before the
//! first launch and STATS never reports a half-pinned pool. Placement
//! **never** changes results — the stress battery replays pinned
//! topologies byte-for-byte against the unpinned oracle — and a failed
//! pin degrades to unpinned execution with one named warning. Under
//! `Compact` on a multi-socket machine, [`DeviceTopology`] also swaps
//! its default round-robin shard map for a socket-major
//! [`Pinning::Explicit`] map, so a shard group's pool, its workers and
//! its arena partition share a socket.

pub mod aot;
pub mod backend;
pub mod topology;

pub use crate::util::affinity::{CpuTopology, PlacementPlan, PlacementPolicy};
pub use aot::AotBackend;
pub use backend::{
    build_backend, build_backend_placed, effective_streams, Backend, BackendKind, Kernel,
    OffloadShape, OffloadStats, PlacementSummary, PoolPlacement, StreamStat,
};
pub use topology::{DeviceTopology, Pinning, TopologyConfig};

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// GPU-like launch geometry.
#[derive(Clone, Copy, Debug)]
pub struct LaunchConfig {
    /// Logical threads per block (CUDA default 256).
    pub block_size: usize,
    /// Logical threads per warp (32 on NVIDIA).
    pub warp_size: usize,
    /// OS worker threads ("SMs"). Defaults to available parallelism.
    pub workers: usize,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        Self {
            block_size: 256,
            warp_size: 32,
            workers: default_workers(),
        }
    }
}

/// Default worker count: `CUCKOO_WORKERS` if set, else the size of the
/// process **affinity mask** (so a run confined to 2 CPUs of a 64-CPU
/// host by a container cpuset spawns 2 workers, not 64), else
/// `available_parallelism`, else 4.
pub fn default_workers() -> usize {
    std::env::var("CUCKOO_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .or_else(|| crate::util::affinity::allowed_cpus().map(|cpus| cpus.len()))
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Per-warp view handed to kernel closures: item range + warp-local
/// success accumulator.
pub struct WarpCtx {
    /// Index range of this warp's items in the launch batch.
    pub range: std::ops::Range<usize>,
    /// Warp-local success tally (the "warp shuffle" reduction level).
    successes: u64,
}

impl WarpCtx {
    #[inline(always)]
    pub fn tally(&mut self, success: bool) {
        self.successes += success as u64;
    }
}

/// A borrowed, type-erased pool task: invoked once per worker with the
/// worker index. Published by reference for the duration of one job;
/// the submitting thread parks on the job's completion, which retires
/// the borrow before its frame returns.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared invocation from many workers is
// its contract) and outlives the job — workers only dereference between
// job publication and their completion decrement, both of which happen
// while the launching thread is parked on the job's completion.
unsafe impl Send for TaskRef {}

/// How a job's kernel closure is owned.
#[derive(Clone)]
enum TaskKind {
    /// Synchronous launch: caller-stack borrow (see [`TaskRef`]).
    Borrowed(TaskRef),
    /// Async launch: heap-owned closure that outlives the submitting
    /// frame — no lifetime erasure, the job owns its captures.
    Owned(Arc<dyn Fn(usize) + Send + Sync>),
}

/// Per-job completion state: the token side of an async launch, and the
/// barrier the synchronous path parks on.
struct Completion {
    state: Mutex<CompletionState>,
    cv: Condvar,
    /// The job's hierarchical success count ("one global atomic per
    /// block" commits land here for async jobs).
    successes: AtomicU64,
}

#[derive(Default)]
struct CompletionState {
    done: bool,
    panicked: bool,
}

impl Completion {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(CompletionState::default()),
            cv: Condvar::new(),
            successes: AtomicU64::new(0),
        })
    }

    /// An already-retired completion (empty or inline-executed jobs).
    fn completed(successes: u64, panicked: bool) -> Arc<Self> {
        let c = Self::new();
        c.successes.store(successes, Ordering::Relaxed);
        let mut st = c.state.lock().unwrap();
        st.done = true;
        st.panicked = panicked;
        drop(st);
        c
    }

    fn finish(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.done = true;
        st.panicked = panicked;
        drop(st);
        self.cv.notify_all();
    }

    /// Park until the job retires; returns whether a worker panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while !st.done {
            st = self.cv.wait(st).unwrap();
        }
        st.panicked
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().done
    }
}

/// One queued unit of device work.
struct Job {
    task: TaskKind,
    completion: Arc<Completion>,
}

struct PoolState {
    /// Monotone publication counter; a bump tells workers a new job is
    /// current. Doubles as the jobs-started ledger for [`Device::pool_jobs`].
    epoch: u64,
    /// The job the workers are executing, if any.
    current: Option<Job>,
    /// Jobs submitted behind `current`, FIFO (the single CUDA stream).
    queue: VecDeque<Job>,
    /// Workers that have not yet retired the current job.
    remaining: usize,
    /// A worker's kernel panicked during the current job.
    panicked: bool,
    shutdown: bool,
}

/// Spawn-time pinning plan plus the per-worker outcome ledger for one
/// pool. Workers pin **themselves** in their prologue (the affinity
/// syscall targets the calling thread); [`WorkerPool::new`] parks until
/// every worker has recorded an outcome, so placement state is settled
/// before the first launch.
struct PinPlan {
    /// Target CPU per worker (len == pool size).
    cpus: Vec<usize>,
    /// Workers whose pin attempt succeeded.
    ok: AtomicU64,
    /// Workers whose pin attempt failed (they run unpinned).
    failed: AtomicU64,
    /// Workers that have recorded an outcome; construction waits for
    /// this to reach the pool size.
    recorded: Mutex<usize>,
    recorded_cv: Condvar,
    /// One named warning per pool on pin failure, not one per worker.
    warned: AtomicBool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// Pool width, needed by the last-finishing worker to arm the next job.
    size: usize,
    /// Jobs submitted but not yet retired. The inline fast paths consult
    /// this so a small launch never jumps ahead of queued jobs — FIFO
    /// stream order holds for any single submitter.
    inflight: AtomicU64,
    /// `Some` when this pool's workers pin themselves at spawn.
    pin: Option<PinPlan>,
}

struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
    /// Lifetime total of OS threads spawned (== `size`; the reuse tests
    /// assert it never grows with launches).
    spawned: AtomicU64,
}

impl WorkerPool {
    /// Spawn `size` workers. `pin_cpus` (non-empty) pins worker `j` to
    /// `pin_cpus[j % len]` in its prologue; construction then waits for
    /// every worker's pin outcome before returning.
    fn new(size: usize, pin_cpus: Option<Vec<usize>>) -> Self {
        let pin = pin_cpus.filter(|c| !c.is_empty()).map(|cpus| PinPlan {
            cpus: (0..size).map(|j| cpus[j % cpus.len()]).collect(),
            ok: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            recorded: Mutex::new(0),
            recorded_cv: Condvar::new(),
            warned: AtomicBool::new(false),
        });
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                current: None,
                queue: VecDeque::new(),
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            size,
            inflight: AtomicU64::new(0),
            pin,
        });
        let spawned = AtomicU64::new(0);
        let handles = (0..size)
            .map(|w| {
                spawned.fetch_add(1, Ordering::Relaxed);
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cuckoo-sm-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("failed to spawn device worker")
            })
            .collect();
        if let Some(pin) = &shared.pin {
            // Settle placement before the first launch: every worker has
            // either landed on its core or been counted as failed.
            let mut done = pin.recorded.lock().unwrap();
            while *done < size {
                done = pin.recorded_cv.wait(done).unwrap();
            }
        }
        Self {
            shared,
            handles,
            size,
            spawned,
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        // Workers drain the queue before exiting, so every outstanding
        // LaunchToken still completes.
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, worker: usize) {
    if let Some(pin) = &shared.pin {
        // Spawn-time pinning: the syscall targets the calling thread, so
        // it must run here, before the first job, not in the spawner.
        match crate::util::affinity::pin_current_thread(&[pin.cpus[worker]]) {
            Ok(()) => {
                pin.ok.fetch_add(1, Ordering::Relaxed);
            }
            Err(why) => {
                pin.failed.fetch_add(1, Ordering::Relaxed);
                if !pin.warned.swap(true, Ordering::Relaxed) {
                    eprintln!("[cuckoo-gpu] warn: worker pinning degraded to unpinned: {why}");
                }
            }
        }
        let mut done = pin.recorded.lock().unwrap();
        *done += 1;
        pin.recorded_cv.notify_all();
    }
    let mut seen_epoch = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.epoch > seen_epoch {
                    seen_epoch = st.epoch;
                    let cur = st.current.as_ref().expect("epoch bumped without a job");
                    break cur.task.clone();
                }
                if st.shutdown && st.current.is_none() && st.queue.is_empty() {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let outcome = match &task {
            TaskKind::Borrowed(r) => {
                // SAFETY: see `TaskRef` — the submitter keeps the pointee
                // alive until every worker has retired the job below.
                let kernel: &(dyn Fn(usize) + Sync) = unsafe { &*r.0 };
                catch_unwind(AssertUnwindSafe(|| kernel(worker)))
            }
            TaskKind::Owned(f) => catch_unwind(AssertUnwindSafe(|| f(worker))),
        };
        // Release this worker's task handle before retiring the job, so a
        // completed job holds no stray clones of its owned state.
        drop(task);
        let mut st = shared.state.lock().unwrap();
        if outcome.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            let job = st.current.take().expect("job retired with no current");
            let panicked = st.panicked;
            // Release pairs with the inline paths' Acquire: a submitter
            // that observes the count hit zero also sees this job's
            // effects.
            shared.inflight.fetch_sub(1, Ordering::Release);
            // FIFO hand-over: the last worker out arms the next job.
            if let Some(next) = st.queue.pop_front() {
                st.current = Some(next);
                st.remaining = shared.size;
                st.panicked = false;
                st.epoch += 1;
            }
            drop(st);
            // Wake peers for the next job, or (on shutdown) to exit.
            shared.work_cv.notify_all();
            job.completion.finish(panicked);
        }
    }
}

/// Completion handle for an async launch (see the module docs for the
/// token lifecycle). Obtained from [`Device::launch_async`].
pub struct LaunchToken {
    completion: Arc<Completion>,
}

impl LaunchToken {
    /// Block until the job retires; returns the hierarchical success
    /// count. Panics with "device worker panicked" if the kernel
    /// panicked — the panic surfaces here, never at submit.
    pub fn wait(self) -> u64 {
        if self.completion.wait() {
            panic!("device worker panicked");
        }
        self.completion.successes.load(Ordering::Acquire)
    }

    /// Non-blocking completion probe.
    pub fn is_done(&self) -> bool {
        self.completion.is_done()
    }
}

/// The batch execution device: launch geometry + the persistent pool.
pub struct Device {
    pub cfg: LaunchConfig,
    pool: WorkerPool,
    /// Placement policy label this pool was built under ("none" for an
    /// unpinned device) — surfaced in the STATS `placement:` row.
    pin_policy: &'static str,
    /// Lifetime count of non-empty launches through any entry point
    /// (inline fast paths included, unlike the pool job ledger).
    launches: AtomicU64,
}

impl Default for Device {
    fn default() -> Self {
        Self::new(LaunchConfig::default())
    }
}

impl Device {
    pub fn new(cfg: LaunchConfig) -> Self {
        Self::with_placement(cfg, Vec::new(), "none")
    }

    pub fn with_workers(workers: usize) -> Self {
        Self::new(LaunchConfig {
            workers: workers.max(1),
            ..LaunchConfig::default()
        })
    }

    /// Build a device whose workers pin themselves at spawn: worker `j`
    /// pins to `cpus[j % cpus.len()]` (empty = unpinned, identical to
    /// [`Device::new`]). `policy` is the placement label reported by
    /// [`Backend::placement`]. See the module docs ("Hardware
    /// placement") for why pinning happens only at spawn.
    pub fn with_placement(cfg: LaunchConfig, cpus: Vec<usize>, policy: &'static str) -> Self {
        let size = cfg.workers.max(1);
        let pin = if cpus.is_empty() { None } else { Some(cpus) };
        Self {
            cfg,
            pool: WorkerPool::new(size, pin),
            pin_policy: policy,
            launches: AtomicU64::new(0),
        }
    }

    /// The placement label this device was built under.
    pub fn pin_policy(&self) -> &'static str {
        self.pin_policy
    }

    /// Per-pool pin ledger: `(target cpus, succeeded, failed)`. Empty
    /// targets = unpinned pool (no attempts were made); otherwise
    /// `succeeded + failed == workers` — every worker's outcome is
    /// recorded before construction returns.
    pub fn pin_outcomes(&self) -> (Vec<usize>, u64, u64) {
        match &self.pool.shared.pin {
            Some(p) => (
                p.cpus.clone(),
                p.ok.load(Ordering::Relaxed),
                p.failed.load(Ordering::Relaxed),
            ),
            None => (Vec::new(), 0, 0),
        }
    }

    /// Number of persistent worker threads ("SMs") in the pool.
    pub fn workers(&self) -> usize {
        self.pool.size
    }

    /// Lifetime total of worker threads ever spawned by this device.
    /// Stays equal to [`Self::workers`] no matter how many launches run —
    /// the observable "spawn once" invariant.
    pub fn threads_spawned(&self) -> u64 {
        self.pool.spawned.load(Ordering::Relaxed)
    }

    /// Number of pool jobs started (inline fast-path launches excluded).
    pub fn pool_jobs(&self) -> u64 {
        self.pool.shared.state.lock().unwrap().epoch
    }

    /// Lifetime count of non-empty launches through any entry point —
    /// unlike [`Self::pool_jobs`], inline fast-path launches count too.
    /// The per-pool launch counter the serving metrics report.
    pub fn launches(&self) -> u64 {
        self.launches.load(Ordering::Relaxed)
    }

    /// Jobs submitted but not yet retired (the live stream depth this
    /// pool's metrics report). Inline fast-path launches never appear
    /// here — they only run on an idle pool.
    pub fn queue_depth(&self) -> u64 {
        self.pool.shared.inflight.load(Ordering::Relaxed)
    }

    /// Whether no job is submitted-but-unretired. Gates the inline fast
    /// paths: running a small launch on the caller thread is only legal
    /// when nothing is queued ahead of it, otherwise it would overtake
    /// the FIFO stream. The Acquire load pairs with the retiring
    /// worker's Release so an idle observation also sees the retired
    /// jobs' effects.
    #[inline]
    fn pool_idle(&self) -> bool {
        self.pool.shared.inflight.load(Ordering::Acquire) == 0
    }

    /// Enqueue a job (FIFO). If the pool is idle the job is published to
    /// the workers immediately; otherwise it waits behind `current`.
    /// (Internal queue step — the public submission surfaces are
    /// [`Self::launch`], [`Self::launch_async`] and [`Backend::submit`].)
    fn enqueue(&self, task: TaskKind, completion: Arc<Completion>) {
        let shared = &*self.pool.shared;
        let job = Job { task, completion };
        let mut st = shared.state.lock().unwrap();
        shared.inflight.fetch_add(1, Ordering::Relaxed);
        if st.current.is_none() {
            debug_assert!(st.queue.is_empty(), "queued jobs with an idle pool");
            st.current = Some(job);
            st.remaining = shared.size;
            st.panicked = false;
            st.epoch += 1;
            drop(st);
            shared.work_cv.notify_all();
        } else {
            st.queue.push_back(job);
        }
    }

    /// Synchronous pool job: publish `task`, park on its completion.
    fn run_job(&self, task: &(dyn Fn(usize) + Sync)) {
        // Erase the caller-stack lifetime; the completion wait below
        // retires the borrow before this frame returns (see module docs).
        let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        let completion = Completion::new();
        self.enqueue(TaskKind::Borrowed(TaskRef(task as *const _)), completion.clone());
        if completion.wait() {
            panic!("device worker panicked");
        }
    }

    /// Launch a "kernel" over `n` items and wait for it. `kernel` is
    /// invoked once per *warp* with a [`WarpCtx`]; it processes
    /// `ctx.range` and tallies successes. Returns the total success
    /// count, committed with one atomic addition per block (hierarchical
    /// reduction).
    pub fn launch<F>(&self, n: usize, kernel: F) -> u64
    where
        F: Fn(&mut WarpCtx) + Sync,
    {
        if n == 0 {
            return 0;
        }
        self.launches.fetch_add(1, Ordering::Relaxed);
        let bs = self.cfg.block_size.max(1);
        let ws = self.cfg.warp_size.max(1);
        let num_blocks = n.div_ceil(bs);
        let global = AtomicU64::new(0);

        if (num_blocks == 1 || self.pool.size == 1) && self.pool_idle() {
            // Inline fast path: a one-block grid (or one-worker pool) has
            // no parallelism to exploit — skip the wakeup entirely. Only
            // legal on an idle pool: with jobs in flight the launch must
            // queue behind them (FIFO stream order).
            for block in 0..num_blocks {
                run_block(&kernel, block, bs, ws, n, &global);
            }
            return global.load(Ordering::Acquire);
        }

        // The hardware block scheduler: workers race on a shared cursor.
        let cursor = AtomicUsize::new(0);
        let task = |_worker: usize| loop {
            let block = cursor.fetch_add(1, Ordering::Relaxed);
            if block >= num_blocks {
                break;
            }
            run_block(&kernel, block, bs, ws, n, &global);
        };
        self.run_job(&task);
        global.load(Ordering::Acquire)
    }

    /// Stream-ordered launch: submit a kernel over `n` items and return
    /// a [`LaunchToken`] without waiting. Jobs run FIFO behind whatever
    /// is already queued; the token's [`LaunchToken::wait`] yields the
    /// hierarchical success count. The kernel must own its captures
    /// (`'static`) — buffer lifetimes may not lean on the caller's
    /// frame, which returns immediately.
    ///
    /// On an idle pool, single-block grids (and one-worker pools)
    /// execute inline at submit and hand back an already-completed
    /// token — a kernel panic is still deferred to `wait()`. With jobs
    /// in flight the launch always queues, preserving FIFO order.
    pub fn launch_async<F>(&self, n: usize, kernel: F) -> LaunchToken
    where
        F: Fn(&mut WarpCtx) + Send + Sync + 'static,
    {
        if n == 0 {
            return LaunchToken {
                completion: Completion::completed(0, false),
            };
        }
        self.launches.fetch_add(1, Ordering::Relaxed);
        let bs = self.cfg.block_size.max(1);
        let ws = self.cfg.warp_size.max(1);
        let num_blocks = n.div_ceil(bs);

        if (num_blocks == 1 || self.pool.size == 1) && self.pool_idle() {
            let global = AtomicU64::new(0);
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                for block in 0..num_blocks {
                    run_block(&kernel, block, bs, ws, n, &global);
                }
            }));
            return LaunchToken {
                completion: Completion::completed(global.load(Ordering::Acquire), outcome.is_err()),
            };
        }

        let completion = Completion::new();
        let task: Arc<dyn Fn(usize) + Send + Sync> = {
            let completion = completion.clone();
            let cursor = AtomicUsize::new(0);
            Arc::new(move |_worker: usize| loop {
                let block = cursor.fetch_add(1, Ordering::Relaxed);
                if block >= num_blocks {
                    break;
                }
                run_block(&kernel, block, bs, ws, n, &completion.successes);
            })
        };
        self.enqueue(TaskKind::Owned(task), completion.clone());
        LaunchToken { completion }
    }

    /// Convenience: launch over items with a per-item closure returning
    /// success. Still reduces hierarchically.
    pub fn launch_items<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        self.launch(n, |ctx| {
            for i in ctx.range.clone() {
                ctx.tally(f(i));
            }
        })
    }

    /// Launch with a per-item predicate, writing each item's outcome into
    /// `out` (disjoint writes, warp ranges never overlap). Returns the
    /// success count, reduced hierarchically.
    pub fn launch_map<F>(&self, f: F, out: &mut [bool]) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        let n = out.len();
        let ptr = SendMutPtr(out.as_mut_ptr());
        self.launch(n, |ctx| {
            let ptr = &ptr;
            for i in ctx.range.clone() {
                let ok = f(i);
                unsafe { *ptr.0.add(i) = ok };
                ctx.tally(ok);
            }
        })
    }

    /// Partition `n` items into per-worker contiguous shards and run one
    /// closure per shard with the shard index — used when each worker
    /// needs its own mutable scratch (e.g. trace probes).
    pub fn launch_sharded<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        self.launches.fetch_add(1, Ordering::Relaxed);
        let workers = self.pool.size;
        let chunk = n.div_ceil(workers).max(1);
        if workers == 1 && self.pool_idle() {
            f(0, 0..n);
            return;
        }
        let task = |w: usize| {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            if lo < hi {
                f(w, lo..hi);
            }
        };
        self.run_job(&task);
    }
}

/// One block's warp loop: block-level accumulator ("shared memory"),
/// one global atomic per block (§4.3).
#[inline]
fn run_block<F>(kernel: &F, block: usize, bs: usize, ws: usize, n: usize, global: &AtomicU64)
where
    F: Fn(&mut WarpCtx) + Sync,
{
    let block_start = block * bs;
    let block_end = (block_start + bs).min(n);
    let mut block_successes = 0u64;
    let mut w = block_start;
    while w < block_end {
        let mut ctx = WarpCtx {
            range: w..(w + ws).min(block_end),
            successes: 0,
        };
        kernel(&mut ctx);
        // Warp reduction joins the block tally.
        block_successes += ctx.successes;
        w += ws;
    }
    global.fetch_add(block_successes, Ordering::Relaxed);
}

/// Raw-pointer wrapper for disjoint parallel writes across the pool
/// boundary — the crate's single blessed escape hatch for "each logical
/// thread writes its own slot" kernels (`launch_map`, the filter batch
/// ops, the fused shard scatter-back).
///
/// SAFETY contract for users: every write through the pointer must go to
/// an index no other concurrent writer of the same launch touches, and
/// the pointee must outlive the launch (guaranteed by the launch
/// barrier, or by `Arc`-owning the pointee in async task state).
pub(crate) struct SendMutPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendMutPtr<T> {}
unsafe impl<T> Send for SendMutPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn launch_counts_successes() {
        let d = Device::with_workers(4);
        // Every third item "succeeds".
        let got = d.launch_items(10_000, |i| i % 3 == 0);
        let expect = (0..10_000).filter(|i| i % 3 == 0).count() as u64;
        assert_eq!(got, expect);
    }

    #[test]
    fn launch_covers_every_item_exactly_once() {
        let d = Device::new(LaunchConfig {
            block_size: 64,
            warp_size: 8,
            workers: 7,
        });
        let n = 12_345;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch(n, |ctx| {
            for i in ctx.range.clone() {
                hits[i].fetch_add(1, Ordering::Relaxed);
                ctx.tally(true);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_launch() {
        let d = Device::default();
        assert_eq!(d.launch_items(0, |_| true), 0);
    }

    #[test]
    fn empty_async_launch_is_immediately_done() {
        let d = Device::default();
        let tok = d.launch_async(0, |_| {});
        assert!(tok.is_done());
        assert_eq!(tok.wait(), 0);
    }

    #[test]
    fn sharded_partitions() {
        let d = Device::with_workers(3);
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch_sharded(n, |_w, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_still_works() {
        let d = Device::with_workers(1);
        assert_eq!(d.launch_items(100, |_| true), 100);
        // Async on a one-worker pool runs inline and completes at submit.
        let tok = d.launch_async(10_000, |ctx| {
            for i in ctx.range.clone() {
                ctx.tally(i % 2 == 0);
            }
        });
        assert!(tok.is_done());
        assert_eq!(tok.wait(), 5_000);
    }

    #[test]
    fn pool_spawns_threads_exactly_once() {
        let d = Device::with_workers(4);
        for round in 0..150u64 {
            // Multi-block grids so the pool path (not the inline path)
            // is exercised.
            let n = 4096;
            assert_eq!(d.launch_items(n, |i| i as u64 % 2 == round % 2), n as u64 / 2);
        }
        assert_eq!(d.threads_spawned(), 4);
        assert!(d.pool_jobs() >= 150);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let d = Device::with_workers(2);
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            d.launch_items(10_000, |i| {
                if i == 5_000 {
                    panic!("kernel fault");
                }
                true
            });
        }));
        assert!(boom.is_err());
        // The pool must still be serviceable after a kernel panic.
        assert_eq!(d.launch_items(10_000, |_| true), 10_000);
        assert_eq!(d.threads_spawned(), 2);
    }

    #[test]
    fn small_launches_do_not_overtake_queued_jobs() {
        // Regression: the inline fast path must not run a 1-block launch
        // ahead of jobs already in the FIFO queue.
        let d = Device::with_workers(4);
        let n1 = 1 << 15;
        let counter = Arc::new(AtomicU64::new(0));
        let c = counter.clone();
        let big = d.launch_async(n1, move |ctx| {
            for _ in ctx.range.clone() {
                c.fetch_add(1, Ordering::Relaxed);
            }
        });
        // 1-block async launch: must queue behind `big`, so every item
        // observes the fully-incremented counter.
        let c = counter.clone();
        let small = d.launch_async(64, move |ctx| {
            let seen = c.load(Ordering::Relaxed);
            for _ in ctx.range.clone() {
                ctx.tally(seen == n1 as u64);
            }
        });
        assert_eq!(small.wait(), 64, "small launch overtook the queue");
        assert_eq!(big.wait(), 0);
        // 1-block sync launch behind a queued job: same guarantee.
        counter.store(0, Ordering::Relaxed);
        let c = counter.clone();
        let big = d.launch_async(n1, move |ctx| {
            for _ in ctx.range.clone() {
                c.fetch_add(1, Ordering::Relaxed);
            }
        });
        let seen = d.launch_items(64, |_| counter.load(Ordering::Relaxed) == n1 as u64);
        assert_eq!(seen, 64, "sync inline launch overtook the queue");
        big.wait();
    }

    #[test]
    fn unpinned_device_reports_no_pin_attempts() {
        let d = Device::with_workers(2);
        assert_eq!(d.pin_policy(), "none");
        assert_eq!(d.pin_outcomes(), (Vec::new(), 0, 0));
    }

    #[test]
    fn pinned_device_records_every_worker_outcome_before_first_launch() {
        // Pin to CPUs from the live affinity mask where readable (the
        // attempts then succeed); elsewhere the attempts fail with a
        // named warning — either way every worker's outcome is recorded
        // and results are unchanged.
        let targets = crate::util::affinity::allowed_cpus().unwrap_or_else(|| vec![0]);
        let d = Device::with_placement(
            LaunchConfig {
                workers: 3,
                ..LaunchConfig::default()
            },
            targets.clone(),
            "compact",
        );
        assert_eq!(d.pin_policy(), "compact");
        let (cpus, ok, failed) = d.pin_outcomes();
        assert_eq!(cpus.len(), 3, "one target per worker");
        assert!(cpus.iter().all(|c| targets.contains(c)));
        assert_eq!(ok + failed, 3, "an outcome per worker, settled at construction");
        // Pinned pools execute identically.
        assert_eq!(d.launch_items(10_000, |i| i % 2 == 0), 5_000);
        assert_eq!(d.threads_spawned(), 3);
    }

    #[test]
    fn async_launch_fifo_with_sync_launches() {
        let d = Device::with_workers(4);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        let tok = d.launch_async(8_192, move |ctx| {
            for _ in ctx.range.clone() {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        // A sync launch submitted behind the async job completes only
        // after it (FIFO), so the async side effects are fully visible.
        assert_eq!(d.launch_items(4_096, |_| true), 4_096);
        assert_eq!(hits.load(Ordering::Relaxed), 8_192);
        assert_eq!(tok.wait(), 0); // kernel tallied nothing
    }
}
