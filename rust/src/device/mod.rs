//! The batch "kernel launch" engine — the CPU stand-in for the CUDA
//! device (§2.2 / §4.3).
//!
//! A [`Device`] owns a logical worker topology shaped like a GPU grid:
//! a batch of N items is decomposed into *blocks* of `block_size`
//! logical threads, blocks are distributed over OS worker threads
//! (the "SMs"), and inside a block, per-*warp* partial results are
//! reduced before a single atomic commit per block — the paper's
//! hierarchical occupancy counting (warp shuffle → shared memory →
//! one global atomic, §4.3 last paragraph).
//!
//! The engine is deliberately simple: a launch is synchronous (like a
//! stream-ordered kernel + sync), work distribution is an atomic block
//! cursor (the GPU's hardware block scheduler), and scoped threads keep
//! borrows safe without `Arc` gymnastics.

use crossbeam_utils::thread as cb;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// GPU-like launch geometry.
#[derive(Clone, Copy, Debug)]
pub struct LaunchConfig {
    /// Logical threads per block (CUDA default 256).
    pub block_size: usize,
    /// Logical threads per warp (32 on NVIDIA).
    pub warp_size: usize,
    /// OS worker threads ("SMs"). Defaults to available parallelism.
    pub workers: usize,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        Self {
            block_size: 256,
            warp_size: 32,
            workers: default_workers(),
        }
    }
}

pub fn default_workers() -> usize {
    std::env::var("CUCKOO_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Per-warp view handed to kernel closures: item range + warp-local
/// success accumulator.
pub struct WarpCtx {
    /// Index range of this warp's items in the launch batch.
    pub range: std::ops::Range<usize>,
    /// Warp-local success tally (the "warp shuffle" reduction level).
    successes: u64,
}

impl WarpCtx {
    #[inline(always)]
    pub fn tally(&mut self, success: bool) {
        self.successes += success as u64;
    }
}

/// The batch execution device.
pub struct Device {
    pub cfg: LaunchConfig,
}

impl Default for Device {
    fn default() -> Self {
        Self::new(LaunchConfig::default())
    }
}

impl Device {
    pub fn new(cfg: LaunchConfig) -> Self {
        Self { cfg }
    }

    pub fn with_workers(workers: usize) -> Self {
        Self::new(LaunchConfig {
            workers: workers.max(1),
            ..LaunchConfig::default()
        })
    }

    /// Launch a "kernel" over `n` items. `kernel` is invoked once per
    /// *warp* with a [`WarpCtx`]; it processes `ctx.range` and tallies
    /// successes. Returns the total success count, committed with one
    /// atomic addition per block (hierarchical reduction).
    pub fn launch<F>(&self, n: usize, kernel: F) -> u64
    where
        F: Fn(&mut WarpCtx) + Sync,
    {
        if n == 0 {
            return 0;
        }
        let bs = self.cfg.block_size;
        let ws = self.cfg.warp_size;
        let num_blocks = n.div_ceil(bs);
        let cursor = AtomicUsize::new(0);
        let global = AtomicU64::new(0);
        let workers = self.cfg.workers.min(num_blocks).max(1);

        cb::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|_| {
                    loop {
                        // The hardware block scheduler: grab the next block.
                        let block = cursor.fetch_add(1, Ordering::Relaxed);
                        if block >= num_blocks {
                            break;
                        }
                        let block_start = block * bs;
                        let block_end = (block_start + bs).min(n);
                        // Block-level accumulator ("shared memory").
                        let mut block_successes = 0u64;
                        let mut w = block_start;
                        while w < block_end {
                            let mut ctx = WarpCtx {
                                range: w..(w + ws).min(block_end),
                                successes: 0,
                            };
                            kernel(&mut ctx);
                            // Warp reduction joins the block tally.
                            block_successes += ctx.successes;
                            w += ws;
                        }
                        // One global atomic per block (§4.3).
                        global.fetch_add(block_successes, Ordering::Relaxed);
                    }
                });
            }
        })
        .expect("device worker panicked");

        global.load(Ordering::Acquire)
    }

    /// Convenience: launch over items with a per-item closure returning
    /// success. Still reduces hierarchically.
    pub fn launch_items<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        self.launch(n, |ctx| {
            for i in ctx.range.clone() {
                ctx.tally(f(i));
            }
        })
    }

    /// Launch with a per-item predicate, writing each item's outcome into
    /// `out` (disjoint writes, warp ranges never overlap). Returns the
    /// success count, reduced hierarchically.
    pub fn launch_map<F>(&self, f: F, out: &mut [bool]) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        let n = out.len();
        let ptr = SendMutPtr(out.as_mut_ptr());
        self.launch(n, |ctx| {
            let ptr = &ptr;
            for i in ctx.range.clone() {
                let ok = f(i);
                unsafe { *ptr.0.add(i) = ok };
                ctx.tally(ok);
            }
        })
    }

    /// Partition `n` items into per-worker contiguous shards and run one
    /// closure per shard with the shard index — used when each worker
    /// needs its own mutable scratch (e.g. trace probes).
    pub fn launch_sharded<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Sync,
    {
        let workers = self.cfg.workers.max(1);
        let chunk = n.div_ceil(workers).max(1);
        cb::scope(|scope| {
            for w in 0..workers {
                let lo = (w * chunk).min(n);
                let hi = ((w + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                let f = &f;
                scope.spawn(move |_| f(w, lo..hi));
            }
        })
        .expect("device worker panicked");
    }
}

/// Raw-pointer wrapper for disjoint parallel writes across the scoped-
/// thread boundary.
struct SendMutPtr<T>(*mut T);
unsafe impl<T> Sync for SendMutPtr<T> {}
unsafe impl<T> Send for SendMutPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn launch_counts_successes() {
        let d = Device::with_workers(4);
        // Every third item "succeeds".
        let got = d.launch_items(10_000, |i| i % 3 == 0);
        let expect = (0..10_000).filter(|i| i % 3 == 0).count() as u64;
        assert_eq!(got, expect);
    }

    #[test]
    fn launch_covers_every_item_exactly_once() {
        let d = Device::new(LaunchConfig {
            block_size: 64,
            warp_size: 8,
            workers: 7,
        });
        let n = 12_345;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch(n, |ctx| {
            for i in ctx.range.clone() {
                hits[i].fetch_add(1, Ordering::Relaxed);
                ctx.tally(true);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_launch() {
        let d = Device::default();
        assert_eq!(d.launch_items(0, |_| true), 0);
    }

    #[test]
    fn sharded_partitions() {
        let d = Device::with_workers(3);
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch_sharded(n, |_w, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_still_works() {
        let d = Device::with_workers(1);
        assert_eq!(d.launch_items(100, |_| true), 100);
    }
}
