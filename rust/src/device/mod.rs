//! The batch "kernel launch" engine — the CPU stand-in for the CUDA
//! device (§2.2 / §4.3), built around a **persistent worker pool**.
//!
//! A [`Device`] owns a logical worker topology shaped like a GPU grid:
//! a batch of N items is decomposed into *blocks* of `block_size`
//! logical threads, blocks are distributed over OS worker threads
//! (the "SMs"), and inside a block, per-*warp* partial results are
//! reduced before a single atomic commit per block — the paper's
//! hierarchical occupancy counting (warp shuffle → shared memory →
//! one global atomic, §4.3 last paragraph).
//!
//! ## Execution model: launch = enqueue + barrier, not spawn
//!
//! Worker threads are spawned **exactly once**, when the [`Device`] is
//! constructed — the analogue of initialising the GPU and its SMs at
//! context creation. A [`Device::launch`] does *not* create threads; it
//!
//! 1. publishes a type-erased kernel task and bumps the pool **epoch**
//!    (the stream-ordered launch enqueue),
//! 2. wakes the parked workers, which pull blocks from an atomic block
//!    cursor (the hardware block scheduler), and
//! 3. blocks on an **epoch barrier** until every worker has retired the
//!    task (kernel + stream synchronise).
//!
//! Per-launch cost is therefore a condvar wakeup (~µs), not a round of
//! OS thread spawns (~tens of µs × workers) — the difference the paper
//! attributes to cheap stream-ordered launches vs. device reinit, and
//! the reason small serving batches stay cheap. Launches whose grid fits
//! a single block (or a single-worker pool) bypass the pool entirely and
//! run inline on the caller thread, so tiny batches cost no wakeup at
//! all; the `launch_overhead` section of `benches/micro_hot_paths.rs`
//! measures both regimes.
//!
//! Pool jobs are serialised by an internal launch gate (one kernel in
//! flight per device, like a single CUDA stream); concurrent `launch`
//! calls from many threads are safe and simply queue. Kernels must not
//! launch on their own device recursively — that would self-deadlock,
//! exactly like a device-side sync inside a CUDA kernel.
//!
//! Borrow safety: a launch publishes a reference to the caller's stack
//! closure to 'static worker threads. The epoch barrier guarantees every
//! worker is done with the reference before `launch` returns, which is
//! the same contract scoped threads enforce structurally; the lifetime
//! erasure is confined to [`Device::run_job`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// GPU-like launch geometry.
#[derive(Clone, Copy, Debug)]
pub struct LaunchConfig {
    /// Logical threads per block (CUDA default 256).
    pub block_size: usize,
    /// Logical threads per warp (32 on NVIDIA).
    pub warp_size: usize,
    /// OS worker threads ("SMs"). Defaults to available parallelism.
    pub workers: usize,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        Self {
            block_size: 256,
            warp_size: 32,
            workers: default_workers(),
        }
    }
}

pub fn default_workers() -> usize {
    std::env::var("CUCKOO_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Per-warp view handed to kernel closures: item range + warp-local
/// success accumulator.
pub struct WarpCtx {
    /// Index range of this warp's items in the launch batch.
    pub range: std::ops::Range<usize>,
    /// Warp-local success tally (the "warp shuffle" reduction level).
    successes: u64,
}

impl WarpCtx {
    #[inline(always)]
    pub fn tally(&mut self, success: bool) {
        self.successes += success as u64;
    }
}

/// A type-erased pool task: invoked once per worker with the worker
/// index. Published by reference for the duration of one job; the epoch
/// barrier retires the borrow before the launch returns.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared invocation from many workers is
// its contract) and outlives the job — workers only dereference between
// job publication and their completion decrement, both of which happen
// while the launching thread is parked inside `run_job`.
unsafe impl Send for TaskRef {}

struct PoolState {
    /// Monotone job counter; a bump is the "launch enqueued" signal.
    epoch: u64,
    /// The in-flight task, valid while `remaining > 0`.
    task: Option<TaskRef>,
    /// Workers that have not yet retired the current task.
    remaining: usize,
    /// A worker's kernel panicked during the current job.
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The launcher parks here for the epoch barrier.
    done_cv: Condvar,
    /// One kernel in flight per device (a single CUDA stream).
    gate: Mutex<()>,
}

struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
    /// Lifetime total of OS threads spawned (== `size`; the reuse tests
    /// assert it never grows with launches).
    spawned: AtomicU64,
}

impl WorkerPool {
    fn new(size: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                task: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            gate: Mutex::new(()),
        });
        let spawned = AtomicU64::new(0);
        let handles = (0..size)
            .map(|w| {
                spawned.fetch_add(1, Ordering::Relaxed);
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cuckoo-sm-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("failed to spawn device worker")
            })
            .collect();
        Self {
            shared,
            handles,
            size,
            spawned,
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, worker: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen_epoch {
                    seen_epoch = st.epoch;
                    break st.task.expect("pool epoch bumped without a task");
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: see `TaskRef` — the launcher keeps the pointee alive
        // until every worker has decremented `remaining` below.
        let kernel: &(dyn Fn(usize) + Sync) = unsafe { &*task.0 };
        let outcome = catch_unwind(AssertUnwindSafe(|| kernel(worker)));
        let mut st = shared.state.lock().unwrap();
        if outcome.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// The batch execution device: launch geometry + the persistent pool.
pub struct Device {
    pub cfg: LaunchConfig,
    pool: WorkerPool,
}

impl Default for Device {
    fn default() -> Self {
        Self::new(LaunchConfig::default())
    }
}

impl Device {
    pub fn new(cfg: LaunchConfig) -> Self {
        let size = cfg.workers.max(1);
        Self {
            cfg,
            pool: WorkerPool::new(size),
        }
    }

    pub fn with_workers(workers: usize) -> Self {
        Self::new(LaunchConfig {
            workers: workers.max(1),
            ..LaunchConfig::default()
        })
    }

    /// Number of persistent worker threads ("SMs") in the pool.
    pub fn workers(&self) -> usize {
        self.pool.size
    }

    /// Lifetime total of worker threads ever spawned by this device.
    /// Stays equal to [`Self::workers`] no matter how many launches run —
    /// the observable "spawn once" invariant.
    pub fn threads_spawned(&self) -> u64 {
        self.pool.spawned.load(Ordering::Relaxed)
    }

    /// Number of pool jobs retired (inline fast-path launches excluded).
    pub fn pool_jobs(&self) -> u64 {
        self.pool.shared.state.lock().unwrap().epoch
    }

    /// Publish `task` to the pool, wake the workers and wait for the
    /// epoch barrier. One job in flight per device at a time.
    fn run_job(&self, task: &(dyn Fn(usize) + Sync)) {
        let shared = &*self.pool.shared;
        // Scope the gate so it is released (unpoisoned) before a kernel
        // panic propagates — the pool must stay serviceable afterwards.
        let panicked = {
            let _gate = shared.gate.lock().unwrap();
            // Erase the caller-stack lifetime; the barrier below retires
            // the borrow before this frame returns (see module docs).
            let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
            {
                let mut st = shared.state.lock().unwrap();
                st.task = Some(TaskRef(task as *const _));
                st.remaining = self.pool.size;
                st.panicked = false;
                st.epoch += 1;
            }
            shared.work_cv.notify_all();
            let mut st = shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = shared.done_cv.wait(st).unwrap();
            }
            st.task = None;
            let panicked = st.panicked;
            drop(st);
            panicked
        };
        if panicked {
            panic!("device worker panicked");
        }
    }

    /// Launch a "kernel" over `n` items. `kernel` is invoked once per
    /// *warp* with a [`WarpCtx`]; it processes `ctx.range` and tallies
    /// successes. Returns the total success count, committed with one
    /// atomic addition per block (hierarchical reduction).
    pub fn launch<F>(&self, n: usize, kernel: F) -> u64
    where
        F: Fn(&mut WarpCtx) + Sync,
    {
        if n == 0 {
            return 0;
        }
        let bs = self.cfg.block_size.max(1);
        let ws = self.cfg.warp_size.max(1);
        let num_blocks = n.div_ceil(bs);
        let global = AtomicU64::new(0);

        if num_blocks == 1 || self.pool.size == 1 {
            // Inline fast path: a one-block grid (or one-worker pool) has
            // no parallelism to exploit — skip the wakeup entirely.
            for block in 0..num_blocks {
                run_block(&kernel, block, bs, ws, n, &global);
            }
            return global.load(Ordering::Acquire);
        }

        // The hardware block scheduler: workers race on a shared cursor.
        let cursor = AtomicUsize::new(0);
        let task = |_worker: usize| loop {
            let block = cursor.fetch_add(1, Ordering::Relaxed);
            if block >= num_blocks {
                break;
            }
            run_block(&kernel, block, bs, ws, n, &global);
        };
        self.run_job(&task);
        global.load(Ordering::Acquire)
    }

    /// Convenience: launch over items with a per-item closure returning
    /// success. Still reduces hierarchically.
    pub fn launch_items<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        self.launch(n, |ctx| {
            for i in ctx.range.clone() {
                ctx.tally(f(i));
            }
        })
    }

    /// Launch with a per-item predicate, writing each item's outcome into
    /// `out` (disjoint writes, warp ranges never overlap). Returns the
    /// success count, reduced hierarchically.
    pub fn launch_map<F>(&self, f: F, out: &mut [bool]) -> u64
    where
        F: Fn(usize) -> bool + Sync,
    {
        let n = out.len();
        let ptr = SendMutPtr(out.as_mut_ptr());
        self.launch(n, |ctx| {
            let ptr = &ptr;
            for i in ctx.range.clone() {
                let ok = f(i);
                unsafe { *ptr.0.add(i) = ok };
                ctx.tally(ok);
            }
        })
    }

    /// Partition `n` items into per-worker contiguous shards and run one
    /// closure per shard with the shard index — used when each worker
    /// needs its own mutable scratch (e.g. trace probes).
    pub fn launch_sharded<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let workers = self.pool.size;
        let chunk = n.div_ceil(workers).max(1);
        if workers == 1 {
            f(0, 0..n);
            return;
        }
        let task = |w: usize| {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            if lo < hi {
                f(w, lo..hi);
            }
        };
        self.run_job(&task);
    }
}

/// One block's warp loop: block-level accumulator ("shared memory"),
/// one global atomic per block (§4.3).
#[inline]
fn run_block<F>(kernel: &F, block: usize, bs: usize, ws: usize, n: usize, global: &AtomicU64)
where
    F: Fn(&mut WarpCtx) + Sync,
{
    let block_start = block * bs;
    let block_end = (block_start + bs).min(n);
    let mut block_successes = 0u64;
    let mut w = block_start;
    while w < block_end {
        let mut ctx = WarpCtx {
            range: w..(w + ws).min(block_end),
            successes: 0,
        };
        kernel(&mut ctx);
        // Warp reduction joins the block tally.
        block_successes += ctx.successes;
        w += ws;
    }
    global.fetch_add(block_successes, Ordering::Relaxed);
}

/// Raw-pointer wrapper for disjoint parallel writes across the pool
/// boundary — the crate's single blessed escape hatch for "each logical
/// thread writes its own slot" kernels (`launch_map`, the filter batch
/// ops, the fused shard scatter-back).
///
/// SAFETY contract for users: every write through the pointer must go to
/// an index no other concurrent writer of the same launch touches, and
/// the pointee must outlive the launch (guaranteed by the launch
/// barrier).
pub(crate) struct SendMutPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendMutPtr<T> {}
unsafe impl<T> Send for SendMutPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn launch_counts_successes() {
        let d = Device::with_workers(4);
        // Every third item "succeeds".
        let got = d.launch_items(10_000, |i| i % 3 == 0);
        let expect = (0..10_000).filter(|i| i % 3 == 0).count() as u64;
        assert_eq!(got, expect);
    }

    #[test]
    fn launch_covers_every_item_exactly_once() {
        let d = Device::new(LaunchConfig {
            block_size: 64,
            warp_size: 8,
            workers: 7,
        });
        let n = 12_345;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch(n, |ctx| {
            for i in ctx.range.clone() {
                hits[i].fetch_add(1, Ordering::Relaxed);
                ctx.tally(true);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_launch() {
        let d = Device::default();
        assert_eq!(d.launch_items(0, |_| true), 0);
    }

    #[test]
    fn sharded_partitions() {
        let d = Device::with_workers(3);
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        d.launch_sharded(n, |_w, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_still_works() {
        let d = Device::with_workers(1);
        assert_eq!(d.launch_items(100, |_| true), 100);
    }

    #[test]
    fn pool_spawns_threads_exactly_once() {
        let d = Device::with_workers(4);
        for round in 0..150u64 {
            // Multi-block grids so the pool path (not the inline path)
            // is exercised.
            let n = 4096;
            assert_eq!(d.launch_items(n, |i| i as u64 % 2 == round % 2), n as u64 / 2);
        }
        assert_eq!(d.threads_spawned(), 4);
        assert!(d.pool_jobs() >= 150);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let d = Device::with_workers(2);
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            d.launch_items(10_000, |i| {
                if i == 5_000 {
                    panic!("kernel fault");
                }
                true
            });
        }));
        assert!(boom.is_err());
        // The pool must still be serviceable after a kernel panic.
        assert_eq!(d.launch_items(10_000, |_| true), 10_000);
        assert_eq!(d.threads_spawned(), 2);
    }
}
