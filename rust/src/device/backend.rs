//! The unified launch surface: one stream-ordered submission trait over
//! every device shape.
//!
//! A [`Backend`] is *anything that can run kernels*: a single [`Device`]
//! (one FIFO stream, one pool of SMs), a [`DeviceTopology`] (N
//! independent streams with a stable shard → stream assignment), and —
//! the reason the trait exists — whatever comes next (a real GPU behind
//! PJRT, a remote device). Execution-layer code (`ShardedFilter`,
//! `Engine`, the benches) takes `&B: Backend` or `&dyn Backend` and
//! never names a concrete device type, so a new backend is one `impl`,
//! not a fourth copy of every batch path.
//!
//! ## The contract
//!
//! * [`Backend::streams`] — how many independent FIFO submission streams
//!   the backend exposes. Kernels submitted to the *same* stream run in
//!   submission order; kernels on *different* streams may overlap.
//! * [`Backend::stream_for_shard`] — the stable stream that owns a shard.
//!   All batches touching one shard serialise on one stream's queue,
//!   which is what makes per-shard mutation order equal submission order
//!   (the cross-stream analogue of single-stream FIFO).
//! * [`Backend::submit`] — the stream-ordered async launch: enqueue an
//!   owned kernel, get a [`LaunchToken`] back immediately. Token
//!   lifecycle is uniform across backends (wait out of order, drop
//!   without wait, panic re-raised at `wait()` — see the [`super`]
//!   module docs). Synchronous execution is not a separate surface:
//!   sync = `submit` + `wait`.
//! * [`Backend::run`] — the borrowed-kernel barrier launch for callers
//!   whose closures cannot be `'static` (the baselines' trait-object
//!   batches). Equivalent to submit + wait on one stream.
//! * [`Backend::stream_stats`] — per-stream observability (workers,
//!   lifetime launches, live queue depth); the aggregate accessors
//!   default to summing it.

use super::{Device, DeviceTopology, LaunchToken, TopologyConfig, WarpCtx};
use crate::util::affinity::PlacementPolicy;
use std::fmt;
use std::sync::Arc;

/// An owned, type-erased kernel: invoked once per warp with a
/// [`WarpCtx`], shared by every worker of the launch.
pub type Kernel = Arc<dyn Fn(&mut WarpCtx) + Send + Sync>;

/// Which backend family serves an engine: the CLI's `--backend` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// The native worker-pool kernels ([`Device`] / [`DeviceTopology`]).
    #[default]
    Native,
    /// [`super::AotBackend`]: query batches offload onto interpreted AOT
    /// graph executions; mutations run on the wrapped native backend.
    Aot,
}

impl BackendKind {
    /// Parse the CLI token (`native` | `aot`).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "aot" => Some(BackendKind::Aot),
            _ => None,
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Native => "native",
            BackendKind::Aot => "aot",
        })
    }
}

/// The filter geometry a query-offloading backend can serve. A filter
/// whose shape differs must stay on the native kernels — and the
/// mismatch is recorded via [`Backend::note_offload_mismatch`], never
/// silently dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OffloadShape {
    pub num_buckets: usize,
    pub bucket_slots: usize,
    pub seed: u64,
}

/// Counters for the offload path, surfaced in STATS.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OffloadStats {
    /// Interpreted graph executions launched.
    pub launches: u64,
    /// Keys answered through the offload path.
    pub keys: u64,
    /// Offload attempts that errored and fell back to native kernels.
    pub fallbacks: u64,
    /// Geometry mismatches that kept batches on the native path.
    pub mismatches: u64,
    /// The most recent mismatch, verbatim.
    pub last_mismatch: Option<String>,
}

/// Hardware-placement ledger of one pool/stream, for the STATS
/// `placement:` row. Every worker's pin-attempt outcome lands in
/// exactly one of `pinned`/`failed` (or in neither for an unpinned
/// pool, where `cpus` is empty and no attempt was made).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PoolPlacement {
    pub pool: usize,
    /// Persistent workers in this pool.
    pub workers: usize,
    /// Target CPUs the workers were asked to pin to (empty = unpinned).
    pub cpus: Vec<usize>,
    /// Workers whose spawn-time pin succeeded.
    pub pinned: u64,
    /// Workers whose pin attempt failed (running unpinned, warned once).
    pub failed: u64,
}

/// A backend's placement report: the policy it was built under plus the
/// per-pool pin ledgers. See [`Backend::placement`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PlacementSummary {
    /// Placement label (`none`/`compact`/`spread`/`explicit`).
    pub policy: String,
    pub pools: Vec<PoolPlacement>,
}

/// Point-in-time stats of one submission stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamStat {
    pub stream: usize,
    /// Persistent worker threads serving this stream.
    pub workers: usize,
    /// Lifetime count of non-empty launches (inline fast paths included).
    pub launches: u64,
    /// Jobs submitted but not yet retired.
    pub queue_depth: u64,
}

/// The backend-agnostic launch surface (see the module docs).
pub trait Backend: Send + Sync {
    /// Number of independent FIFO submission streams (≥ 1).
    fn streams(&self) -> usize;

    /// The stream that owns shard `shard`; stable for the backend's
    /// lifetime.
    fn stream_for_shard(&self, shard: usize) -> usize;

    /// Stream-ordered launch of `kernel` over `n` items on `stream`;
    /// returns immediately with the job's completion token.
    fn submit(&self, stream: usize, n: usize, kernel: Kernel) -> LaunchToken;

    /// Synchronous barrier launch of a borrowed kernel on `stream`;
    /// returns the hierarchical success count. For owned kernels prefer
    /// [`Backend::submit`] + `wait`.
    fn run(&self, stream: usize, n: usize, kernel: &(dyn Fn(&mut WarpCtx) + Sync)) -> u64;

    /// Per-stream worker/launch/queue counters, in stream order.
    fn stream_stats(&self) -> Vec<StreamStat>;

    /// Total persistent workers across all streams.
    fn workers(&self) -> usize {
        self.stream_stats().iter().map(|s| s.workers).sum()
    }

    /// Lifetime non-empty launches across all streams.
    fn launches(&self) -> u64 {
        self.stream_stats().iter().map(|s| s.launches).sum()
    }

    /// Live submitted-but-unretired jobs across all streams.
    fn queue_depth(&self) -> u64 {
        self.stream_stats().iter().map(|s| s.queue_depth).sum()
    }

    /// Short family name for STATS (`native` | `aot`).
    fn kind(&self) -> &'static str {
        "native"
    }

    /// The filter geometry this backend can answer queries for without
    /// the native kernels, or `None` if it never offloads (the default).
    /// `ShardedFilter::submit` consults this before routing a query
    /// batch to [`Backend::offload_query`].
    fn offload_shape(&self) -> Option<OffloadShape> {
        None
    }

    /// Answer one query batch against a table snapshot through the
    /// offload substrate. Only called after [`Backend::offload_shape`]
    /// matched the live filter; an `Err` sends the batch back to the
    /// native kernels (and is counted as a fallback).
    fn offload_query(&self, _words: Vec<u64>, _keys: &[u64]) -> Result<Vec<bool>, String> {
        Err("backend does not offload queries".into())
    }

    /// Record a geometry mismatch that kept a batch on the native path;
    /// offloading backends count these for STATS so the degradation is
    /// visible, not silent.
    fn note_offload_mismatch(&self, _why: &str) {}

    /// Offload counters for STATS; `None` for backends that never
    /// offload.
    fn offload_stats(&self) -> Option<OffloadStats> {
        None
    }

    /// Hardware-placement report: the policy this backend was built
    /// under and, per pool, the target cores plus every worker's
    /// pin-attempt outcome. The default (for backends without worker
    /// pools of their own) reports each stream as an unpinned pool.
    fn placement(&self) -> PlacementSummary {
        PlacementSummary {
            policy: "none".to_string(),
            pools: self
                .stream_stats()
                .iter()
                .map(|s| PoolPlacement {
                    pool: s.stream,
                    workers: s.workers,
                    ..PoolPlacement::default()
                })
                .collect(),
        }
    }
}

/// One device = one stream.
impl Backend for Device {
    fn streams(&self) -> usize {
        1
    }

    fn stream_for_shard(&self, _shard: usize) -> usize {
        0
    }

    fn submit(&self, stream: usize, n: usize, kernel: Kernel) -> LaunchToken {
        // Same out-of-range contract as a topology (which would panic on
        // pool indexing): a wrong stream id must not silently "work"
        // here and abort only on multi-pool deployments.
        debug_assert!(stream == 0, "stream {stream} out of range for a single-stream Device");
        self.launch_async(n, move |ctx| (*kernel)(ctx))
    }

    fn run(&self, stream: usize, n: usize, kernel: &(dyn Fn(&mut WarpCtx) + Sync)) -> u64 {
        debug_assert!(stream == 0, "stream {stream} out of range for a single-stream Device");
        self.launch(n, kernel)
    }

    fn stream_stats(&self) -> Vec<StreamStat> {
        vec![StreamStat {
            stream: 0,
            workers: self.workers(),
            launches: self.launches(),
            queue_depth: self.queue_depth(),
        }]
    }

    fn placement(&self) -> PlacementSummary {
        let (cpus, pinned, failed) = self.pin_outcomes();
        PlacementSummary {
            policy: self.pin_policy().to_string(),
            pools: vec![PoolPlacement {
                pool: 0,
                workers: self.workers(),
                cpus,
                pinned,
                failed,
            }],
        }
    }
}

/// One stream per pool; shard assignment is the topology's pinning.
impl Backend for DeviceTopology {
    fn streams(&self) -> usize {
        self.num_pools()
    }

    fn stream_for_shard(&self, shard: usize) -> usize {
        self.pool_for_shard(shard)
    }

    fn submit(&self, stream: usize, n: usize, kernel: Kernel) -> LaunchToken {
        self.pool(stream).launch_async(n, move |ctx| (*kernel)(ctx))
    }

    fn run(&self, stream: usize, n: usize, kernel: &(dyn Fn(&mut WarpCtx) + Sync)) -> u64 {
        self.pool(stream).launch(n, kernel)
    }

    fn stream_stats(&self) -> Vec<StreamStat> {
        self.pools()
            .iter()
            .enumerate()
            .map(|(i, d)| StreamStat {
                stream: i,
                workers: d.workers(),
                launches: d.launches(),
                queue_depth: d.queue_depth(),
            })
            .collect()
    }

    fn placement(&self) -> PlacementSummary {
        PlacementSummary {
            policy: self.policy().to_string(),
            pools: self
                .pools()
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    let (cpus, pinned, failed) = d.pin_outcomes();
                    PoolPlacement {
                        pool: i,
                        workers: d.workers(),
                        cpus,
                        pinned,
                        failed,
                    }
                })
                .collect(),
        }
    }
}

/// Build the backend for a `pools`/`total_workers` knob pair: one plain
/// [`Device`] for a single pool, a [`DeviceTopology`] re-partitioning
/// the same worker budget otherwise. The two are observably equivalent
/// at `pools = 1` (enforced by the backend-equivalence battery in
/// `tests/stress_topology.rs`); callers hold a `Box<dyn Backend>` and
/// never learn which they got.
pub fn build_backend(pools: usize, total_workers: usize) -> Box<dyn Backend> {
    build_backend_placed(pools, total_workers, PlacementPolicy::None)
}

/// [`build_backend`] with a worker→core [`PlacementPolicy`].
/// `PlacementPolicy::None` is inert (no topology probe, no syscalls) —
/// identical to the two-argument form. Anything else pins each pool's
/// workers at spawn and reports the outcomes via [`Backend::placement`];
/// see the `device` module docs ("Hardware placement").
pub fn build_backend_placed(
    pools: usize,
    total_workers: usize,
    placement: PlacementPolicy,
) -> Box<dyn Backend> {
    if pools <= 1 {
        let workers = total_workers.max(1);
        let policy = placement.label();
        let plan = placement.plan(&[workers]);
        let cpus = plan.pools.into_iter().next().unwrap_or_default();
        Box::new(Device::with_placement(
            super::LaunchConfig {
                workers,
                ..super::LaunchConfig::default()
            },
            cpus,
            policy,
        ))
    } else {
        Box::new(DeviceTopology::new(TopologyConfig {
            pools,
            total_workers,
            placement,
            ..TopologyConfig::default()
        }))
    }
}

/// The stream count [`build_backend_placed`] will actually produce for
/// a `pools`/`total_workers` knob pair, after the topology's
/// oversubscription clamp. The engine sizes its arena partitions with
/// this *before* the backend exists, so partitions and streams can
/// never disagree.
pub fn effective_streams(pools: usize, total_workers: usize) -> usize {
    if pools <= 1 {
        1
    } else {
        pools.clamp(1, total_workers.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn count_evens(backend: &dyn Backend, stream: usize, n: usize) -> u64 {
        backend
            .submit(
                stream,
                n,
                Arc::new(|ctx: &mut WarpCtx| {
                    for i in ctx.range.clone() {
                        ctx.tally(i % 2 == 0);
                    }
                }),
            )
            .wait()
    }

    #[test]
    fn device_and_topology_share_the_submit_surface() {
        let backends: Vec<Box<dyn Backend>> = vec![
            Box::new(Device::with_workers(2)),
            Box::new(DeviceTopology::with_pools(2, 2)),
        ];
        for b in &backends {
            for stream in 0..b.streams() {
                assert_eq!(count_evens(b.as_ref(), stream, 10_000), 5_000);
            }
            assert_eq!(b.workers(), 2, "budget re-partitioned, never multiplied");
            assert!(b.launches() >= b.streams() as u64);
            let stats = b.stream_stats();
            assert_eq!(stats.len(), b.streams());
            assert_eq!(b.queue_depth(), 0, "all launches drained");
        }
    }

    #[test]
    fn run_executes_borrowed_kernels_synchronously() {
        let topo = DeviceTopology::with_pools(2, 4);
        let hits = AtomicU64::new(0);
        let n = 8_192;
        let ok = Backend::run(&topo, 1, n, &|ctx: &mut WarpCtx| {
            for _ in ctx.range.clone() {
                hits.fetch_add(1, Ordering::Relaxed);
                ctx.tally(true);
            }
        });
        // Barrier semantics: every side effect visible at return.
        assert_eq!(ok, n as u64);
        assert_eq!(hits.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn backend_kind_parses_cli_tokens() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("aot"), Some(BackendKind::Aot));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Native);
        assert_eq!(BackendKind::Aot.to_string(), "aot");
        assert_eq!(BackendKind::Native.to_string(), "native");
    }

    #[test]
    fn native_backends_never_offload() {
        let d = Device::with_workers(1);
        assert_eq!(Backend::kind(&d), "native");
        assert!(Backend::offload_shape(&d).is_none());
        assert!(Backend::offload_stats(&d).is_none());
        assert!(Backend::offload_query(&d, vec![0], &[1]).is_err());
        // The mismatch hook is a no-op for native backends.
        Backend::note_offload_mismatch(&d, "ignored");
    }

    #[test]
    fn build_backend_honours_the_pools_knob() {
        assert_eq!(build_backend(1, 4).streams(), 1);
        let b = build_backend(3, 6);
        assert_eq!(b.streams(), 3);
        assert_eq!(b.workers(), 6);
        // Shard → stream assignment is stable and covers every stream.
        let mut seen = vec![false; b.streams()];
        for s in 0..16 {
            let st = b.stream_for_shard(s);
            assert_eq!(st, b.stream_for_shard(s));
            seen[st] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn effective_streams_matches_what_build_backend_produces() {
        for (pools, workers) in [(0, 4), (1, 4), (2, 4), (4, 2), (8, 3), (3, 0)] {
            assert_eq!(
                effective_streams(pools, workers),
                build_backend(pools, workers).streams(),
                "pools={pools} workers={workers}"
            );
        }
    }

    #[test]
    fn placed_backends_report_per_pool_pin_ledgers() {
        // Unplaced: the two-argument form stays inert, every pool
        // unpinned with zero attempts.
        let b = build_backend(2, 4);
        let p = b.placement();
        assert_eq!(p.policy, "none");
        assert_eq!(p.pools.len(), 2);
        assert!(p.pools.iter().all(|pp| pp.cpus.is_empty() && pp.pinned == 0 && pp.failed == 0));

        // Placed: one target per worker, one recorded outcome per
        // worker, on both backend shapes.
        for pools in [1, 2] {
            let b = build_backend_placed(pools, 4, PlacementPolicy::Compact);
            let p = b.placement();
            assert_eq!(p.policy, "compact");
            assert_eq!(p.pools.len(), pools);
            for pp in &p.pools {
                assert_eq!(pp.cpus.len(), pp.workers);
                assert_eq!(pp.pinned + pp.failed, pp.workers as u64);
            }
            // Placement never changes results.
            assert_eq!(count_evens(b.as_ref(), 0, 10_000), 5_000);
        }
    }
}
