//! [`AotBackend`]: the second `impl Backend` family — query batches
//! offload onto interpreted AOT graph executions.
//!
//! The backend wraps a native backend (any [`Backend`], usually from
//! [`build_backend`](super::build_backend)) and a [`RuntimeHandle`]
//! driving the HLO interpreter on its dedicated thread. The division of
//! labour mirrors the paper's deployment story:
//!
//! * **queries** — `ShardedFilter::submit(.., OpKind::Query, ..)`
//!   consults [`Backend::offload_shape`], snapshots the table, and
//!   routes the batch through [`Backend::offload_query`] → the
//!   interpreter (counted in [`OffloadStats::launches`]);
//! * **inserts/removes** — fall through to the wrapped backend's native
//!   kernels via the unchanged `submit`/`run` stream surface, so
//!   mutation ordering and ticket semantics are identical to a native
//!   deployment.
//!
//! A filter whose geometry (buckets/slots/seed, sharding, post-growth
//! level) doesn't match the loaded artifacts **cannot** be served by
//! the graphs; the shard layer reports that through
//! [`Backend::note_offload_mismatch`], the batch runs natively, and the
//! mismatch is a named, counted event in STATS — never a silent
//! degradation.

use super::backend::{Backend, Kernel, OffloadShape, OffloadStats, PlacementSummary, StreamStat};
use super::LaunchToken;
use crate::runtime::RuntimeHandle;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A [`Backend`] that answers query batches with interpreted AOT graph
/// executions and delegates everything else to a wrapped native
/// backend. See the module docs.
pub struct AotBackend {
    inner: Box<dyn Backend>,
    rt: RuntimeHandle,
    launches: AtomicU64,
    keys: AtomicU64,
    fallbacks: AtomicU64,
    mismatches: AtomicU64,
    last_mismatch: Mutex<Option<String>>,
}

impl AotBackend {
    /// Wrap `inner`, offloading queries onto `rt`'s loaded artifacts.
    pub fn new(inner: Box<dyn Backend>, rt: RuntimeHandle) -> AotBackend {
        AotBackend {
            inner,
            rt,
            launches: AtomicU64::new(0),
            keys: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            mismatches: AtomicU64::new(0),
            last_mismatch: Mutex::new(None),
        }
    }

    /// The runtime handle driving the interpreter.
    pub fn runtime(&self) -> &RuntimeHandle {
        &self.rt
    }
}

impl Backend for AotBackend {
    fn streams(&self) -> usize {
        self.inner.streams()
    }

    fn stream_for_shard(&self, shard: usize) -> usize {
        self.inner.stream_for_shard(shard)
    }

    fn submit(&self, stream: usize, n: usize, kernel: Kernel) -> LaunchToken {
        self.inner.submit(stream, n, kernel)
    }

    fn run(
        &self,
        stream: usize,
        n: usize,
        kernel: &(dyn Fn(&mut super::WarpCtx) + Sync),
    ) -> u64 {
        self.inner.run(stream, n, kernel)
    }

    fn stream_stats(&self) -> Vec<StreamStat> {
        self.inner.stream_stats()
    }

    fn placement(&self) -> PlacementSummary {
        // Pinning lives with the wrapped pools; the interpreter thread
        // is not a pool worker.
        self.inner.placement()
    }

    fn kind(&self) -> &'static str {
        "aot"
    }

    fn offload_shape(&self) -> Option<OffloadShape> {
        let g = &self.rt.geometry;
        Some(OffloadShape {
            num_buckets: g.num_buckets,
            bucket_slots: g.bucket_slots,
            seed: g.seed,
        })
    }

    fn offload_query(&self, words: Vec<u64>, keys: &[u64]) -> Result<Vec<bool>, String> {
        let n = keys.len() as u64;
        match self.rt.query_all(Arc::new(words), keys.to_vec()) {
            Ok(flags) => {
                self.launches.fetch_add(1, Ordering::Relaxed);
                self.keys.fetch_add(n, Ordering::Relaxed);
                Ok(flags)
            }
            Err(e) => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn note_offload_mismatch(&self, why: &str) {
        self.mismatches.fetch_add(1, Ordering::Relaxed);
        *self.last_mismatch.lock().unwrap() = Some(why.to_string());
    }

    fn offload_stats(&self) -> Option<OffloadStats> {
        Some(OffloadStats {
            launches: self.launches.load(Ordering::Relaxed),
            keys: self.keys.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            mismatches: self.mismatches.load(Ordering::Relaxed),
            last_mismatch: self.last_mismatch.lock().unwrap().clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use std::path::PathBuf;

    fn fixture_backend() -> AotBackend {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/aot_64");
        let rt = RuntimeHandle::spawn(dir).unwrap();
        AotBackend::new(Box::new(Device::with_workers(2)), rt)
    }

    #[test]
    fn delegates_streams_and_reports_aot_kind() {
        let b = fixture_backend();
        assert_eq!(b.streams(), 1);
        assert_eq!(b.kind(), "aot");
        let shape = b.offload_shape().unwrap();
        assert_eq!(shape.num_buckets, 64);
        assert_eq!(shape.bucket_slots, 16);
        // Native submit surface still works through the wrapper.
        let ok = Backend::run(&b, 0, 100, &|ctx: &mut crate::device::WarpCtx| {
            for _ in ctx.range.clone() {
                ctx.tally(true);
            }
        });
        assert_eq!(ok, 100);
    }

    #[test]
    fn offload_counters_track_launches_and_mismatches() {
        let b = fixture_backend();
        let words = vec![0u64; 256];
        let flags = b.offload_query(words, &[1, 2, 3]).unwrap();
        assert_eq!(flags.len(), 3);
        b.note_offload_mismatch("geometry mismatch: artifact 'x' vs filter 'y'");
        let stats = b.offload_stats().unwrap();
        assert_eq!(stats.launches, 1);
        assert_eq!(stats.keys, 3);
        assert_eq!(stats.mismatches, 1);
        assert_eq!(stats.fallbacks, 0);
        assert!(stats.last_mismatch.unwrap().contains("artifact 'x'"));
    }

    #[test]
    fn offload_errors_count_as_fallbacks() {
        let b = fixture_backend();
        // Wrong snapshot size: the runtime rejects it; counted, surfaced.
        let e = b.offload_query(vec![0u64; 3], &[1]).unwrap_err();
        assert!(e.contains("3 words"), "{e}");
        assert_eq!(b.offload_stats().unwrap().fallbacks, 1);
    }
}
