//! The shared operation vocabulary of every execution surface.
//!
//! One [`OpKind`] value drives the whole stack: the single-filter batch
//! entry point ([`crate::filter::CuckooFilter::execute_batch`]), the
//! sharded submission API ([`crate::coordinator::ShardedFilter::submit`]),
//! the engine's request loop, the baselines' batched driver
//! ([`crate::baselines::run_batch`]) and the server's line protocol all
//! dispatch on this enum instead of carrying per-op method variants.
//! Adding an execution mode therefore means adding **one** function that
//! matches on `OpKind`, not three.

/// The three dynamic filter operations the paper's kernel serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Insert,
    Query,
    Delete,
}

impl OpKind {
    /// All operations, in protocol order.
    pub const ALL: [OpKind; 3] = [OpKind::Insert, OpKind::Query, OpKind::Delete];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Insert => "insert",
            OpKind::Query => "query",
            OpKind::Delete => "delete",
        }
    }

    /// Whether the op mutates the table (drives the epoch-guard phase).
    pub fn is_mutation(self) -> bool {
        !matches!(self, OpKind::Query)
    }

    /// Parse a protocol token: the full name, its upper-case form, an
    /// alias (`contains`, `remove`) or the single-letter short form
    /// (`i`/`q`/`c`/`d`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "insert" | "INSERT" | "i" => Some(OpKind::Insert),
            "query" | "QUERY" | "q" | "c" | "contains" => Some(OpKind::Query),
            "delete" | "DELETE" | "d" | "remove" => Some(OpKind::Delete),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ops() {
        assert_eq!(OpKind::parse("insert"), Some(OpKind::Insert));
        assert_eq!(OpKind::parse("q"), Some(OpKind::Query));
        assert_eq!(OpKind::parse("remove"), Some(OpKind::Delete));
        assert_eq!(OpKind::parse("nope"), None);
    }

    #[test]
    fn parse_single_letter_forms_cover_every_op() {
        // `c` is the contains/query short form the server protocol
        // accepts alongside `i`/`q`/`d`.
        assert_eq!(OpKind::parse("c"), Some(OpKind::Query));
        assert_eq!(OpKind::parse("i"), Some(OpKind::Insert));
        assert_eq!(OpKind::parse("d"), Some(OpKind::Delete));
    }

    #[test]
    fn parse_roundtrips_through_name() {
        for op in OpKind::ALL {
            assert_eq!(OpKind::parse(op.name()), Some(op), "{op:?}");
            assert_eq!(
                OpKind::parse(&op.name().to_ascii_uppercase()),
                Some(op),
                "{op:?} upper-case"
            );
            // The first letter is the accepted short form for every op
            // except query, which also accepts `c` (contains).
            let letter = &op.name()[..1];
            assert_eq!(OpKind::parse(letter), Some(op), "{op:?} short form");
        }
        assert_eq!(OpKind::parse("contains"), OpKind::parse("c"));
    }

    #[test]
    fn mutation_classes() {
        assert!(OpKind::Insert.is_mutation());
        assert!(OpKind::Delete.is_mutation());
        assert!(!OpKind::Query.is_mutation());
    }
}
