//! GPU memory-system performance model.
//!
//! The paper's evaluation runs on an NVIDIA GH200 (HBM3, 3.4 TB/s) and an
//! RTX PRO 6000 Blackwell (GDDR7, 1.8 TB/s). Neither is available here,
//! so — per the reproduction contract — we *simulate the hardware*: a
//! first-order analytic model of the GPU memory subsystem (§2.2 of the
//! paper: sectors, coalescing, L2 vs DRAM residency, latency-bound
//! dependent accesses, atomic throughput) that converts per-operation
//! access statistics into estimated device throughput.
//!
//! The model is deliberately transparent: four roofline terms —
//! bandwidth, latency×concurrency, compute, atomics — and the minimum
//! wins. Access statistics for *our* filter come from real traces
//! ([`crate::filter::TraceProbe`] attached to the actual lock-free
//! implementation); the baselines get analytic access models derived
//! from their structure (documented per filter in [`filters`]).
//!
//! What this reproduces is the *shape* of the paper's Figures 3, 6 and 7
//! — who wins, by roughly what factor, and how L2-resident vs
//! DRAM-resident scenarios differ — not absolute silicon numbers.

pub mod spec;
pub mod model;
pub mod filters;

pub use model::{estimate, OpClass, OpStats, Residency, ThroughputEstimate};
pub use spec::{DeviceSpec, GH200, RTX_PRO_6000, XEON_W9_DDR5};
