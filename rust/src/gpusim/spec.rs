//! Device descriptors for the paper's three systems (§5.1).

/// First-order hardware description of a memory-bound accelerator.
#[derive(Clone, Copy, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Streaming multiprocessors (or cores for the CPU system).
    pub sms: u32,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Sustained DRAM bandwidth, GB/s.
    pub dram_bw_gbs: f64,
    /// DRAM access latency, ns.
    pub dram_latency_ns: f64,
    /// Unified L2 capacity, bytes.
    pub l2_bytes: usize,
    /// L2 bandwidth, GB/s (several × DRAM on modern parts).
    pub l2_bw_gbs: f64,
    /// L2 hit latency, ns.
    pub l2_latency_ns: f64,
    /// Maximum memory requests in flight device-wide (MLP): pending loads
    /// per SM × SMs. Hides latency when chains are short.
    pub max_inflight: f64,
    /// Scalar integer ops/s device-wide (SMs × clock × lanes × IPC), Gops.
    pub compute_gops: f64,
    /// Sustained atomic CAS/RMW throughput to distinct lines, Gops.
    pub atomic_gops: f64,
}

impl DeviceSpec {
    /// Does a structure of `bytes` fit in L2? (The paper's two scenarios.)
    pub fn l2_resident(&self, bytes: usize) -> bool {
        bytes <= self.l2_bytes
    }
}

/// System B: GH200 Grace-Hopper, H100 GPU, 96 GB HBM3 @ 3.4 TB/s, 132 SMs,
/// 50 MB L2 (§5.1).
pub const GH200: DeviceSpec = DeviceSpec {
    name: "GH200-HBM3",
    sms: 132,
    clock_ghz: 1.83,
    dram_bw_gbs: 3400.0,
    dram_latency_ns: 600.0,
    l2_bytes: 50 * 1024 * 1024,
    l2_bw_gbs: 8000.0,
    l2_latency_ns: 260.0,
    // ~512 outstanding sectors per SM (2048 resident threads with
    // fractional pending loads each; H100-class MSHR depth).
    max_inflight: 132.0 * 512.0,
    // 132 SMs × 1.83 GHz × 128 int lanes ≈ 31 Tops.
    compute_gops: 31_000.0,
    atomic_gops: 20.0,
};

/// System A: RTX PRO 6000 Blackwell, 96 GB GDDR7 @ 1.8 TB/s, 188 SMs,
/// 128 MB L2 (§5.1). ~50% more cores than System B but half the DRAM
/// bandwidth — the compute-vs-bandwidth contrast the paper leans on.
pub const RTX_PRO_6000: DeviceSpec = DeviceSpec {
    name: "RTXPRO6000-GDDR7",
    sms: 188,
    clock_ghz: 2.4,
    dram_bw_gbs: 1800.0,
    dram_latency_ns: 450.0,
    l2_bytes: 128 * 1024 * 1024,
    l2_bw_gbs: 9000.0,
    l2_latency_ns: 240.0,
    max_inflight: 188.0 * 512.0,
    // 188 SMs × 2.4 GHz × 128 lanes ≈ 58 Tops.
    compute_gops: 58_000.0,
    atomic_gops: 24.0,
};

/// System C: Xeon W9-3595X, 60 cores, DDR5 @ 300 GB/s (§5.1) — the PCF
/// test bed.
pub const XEON_W9_DDR5: DeviceSpec = DeviceSpec {
    name: "XeonW9-DDR5",
    sms: 60,
    clock_ghz: 2.0,
    dram_bw_gbs: 300.0,
    dram_latency_ns: 90.0,
    l2_bytes: 120 * 1024 * 1024, // L3, acting as the cache level here
    l2_bw_gbs: 1200.0,
    l2_latency_ns: 25.0,
    // ~12 line-fill buffers per core.
    max_inflight: 60.0 * 12.0,
    // 60 cores × 2 GHz × ~4 IPC scalar.
    compute_gops: 480.0,
    atomic_gops: 1.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_thresholds() {
        // The paper's two scenarios: 2^22 slots (fp16, b independent) is
        // L2-resident (8 MiB), 2^28 slots (512 MiB) is DRAM-resident.
        let l2_bytes = (1usize << 22) * 2;
        let dram_bytes = (1usize << 28) * 2;
        assert!(GH200.l2_resident(l2_bytes));
        assert!(!GH200.l2_resident(dram_bytes));
        assert!(RTX_PRO_6000.l2_resident(l2_bytes));
        assert!(!RTX_PRO_6000.l2_resident(dram_bytes));
    }

    #[test]
    fn bandwidth_ordering() {
        assert!(GH200.dram_bw_gbs > RTX_PRO_6000.dram_bw_gbs);
        assert!(RTX_PRO_6000.sms > GH200.sms);
        assert!(XEON_W9_DDR5.dram_bw_gbs < RTX_PRO_6000.dram_bw_gbs / 4.0);
    }
}
