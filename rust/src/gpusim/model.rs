//! The four-term roofline estimator.
//!
//! An operation class is summarised by per-op access statistics
//! ([`OpStats`]); a device by its [`super::DeviceSpec`]. Estimated
//! throughput is the minimum of:
//!
//! 1. **bandwidth**: `BW / (sectors_per_op × 32 B)` — the paper's claim
//!    is that Cuckoo-GPU is the only dynamic filter that actually reaches
//!    this term on HBM3;
//! 2. **latency × concurrency**: `inflight / (serial_deps × latency)` —
//!    dependent accesses (eviction chains, GQF run shifting) serialise
//!    round trips and cap throughput regardless of bandwidth;
//! 3. **compute**: `compute_gops / cycles_per_op` — the TCF's cooperative
//!    sorting and SWAR arithmetic land here;
//! 4. **atomics**: `atomic_gops / atomics_per_op`, derated by the CAS
//!    failure (retry) fraction.

use super::spec::DeviceSpec;

/// Which memory level the structure lives in (the paper's two scenarios).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    L2,
    Dram,
}

impl Residency {
    pub fn name(self) -> &'static str {
        match self {
            Residency::L2 => "L2-resident",
            Residency::Dram => "DRAM-resident",
        }
    }

    pub fn for_bytes(spec: &DeviceSpec, bytes: usize) -> Self {
        if spec.l2_resident(bytes) {
            Residency::L2
        } else {
            Residency::Dram
        }
    }
}

/// Operation class, for reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    Insert,
    QueryPositive,
    QueryNegative,
    Delete,
}

impl OpClass {
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Insert => "insert",
            OpClass::QueryPositive => "query+",
            OpClass::QueryNegative => "query-",
            OpClass::Delete => "delete",
        }
    }
}

/// Per-operation access statistics (averages over a batch).
#[derive(Clone, Copy, Debug)]
pub struct OpStats {
    /// 32-byte sectors touched per op (after intra-warp coalescing).
    pub sectors_per_op: f64,
    /// Length of the *dependent* access chain (eviction chain steps,
    /// quotient-run shift steps, ...). 1.0 = fully parallel single access.
    pub serial_deps: f64,
    /// Integer-pipe work per op, in scalar-op equivalents.
    pub compute_ops: f64,
    /// Atomic RMW/CAS issued per op.
    pub atomics_per_op: f64,
    /// Fraction of atomics that fail and retry (contention derate).
    pub atomic_retry_frac: f64,
}

impl OpStats {
    /// Build cuckoo-filter stats from a real execution trace.
    pub fn from_trace(trace: &crate::filter::TraceProbe, ops: usize) -> Self {
        let n = ops.max(1) as f64;
        let atomics = trace.atomics as f64 / n;
        // Serial dependency ≈ 1 (hash→bucket) + mean eviction chain.
        let mean_evictions = if trace.eviction_samples.is_empty() {
            0.0
        } else {
            trace.total_evictions() as f64 / trace.eviction_samples.len() as f64
        };
        Self {
            sectors_per_op: trace.sector_touches as f64 / n,
            serial_deps: 1.0 + mean_evictions,
            // SWAR scan cost scales with words read.
            compute_ops: 24.0 + 6.0 * (trace.reads as f64 / n),
            atomics_per_op: atomics,
            atomic_retry_frac: if trace.atomics == 0 {
                0.0
            } else {
                trace.atomic_failures as f64 / trace.atomics as f64
            },
        }
    }
}

/// The estimate plus the binding term, for analysis output.
#[derive(Clone, Copy, Debug)]
pub struct ThroughputEstimate {
    /// Billions of ops per second — the paper's unit.
    pub b_ops: f64,
    pub bound: Bound,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Bandwidth,
    Latency,
    Compute,
    Atomics,
}

impl Bound {
    pub fn name(self) -> &'static str {
        match self {
            Bound::Bandwidth => "bandwidth",
            Bound::Latency => "latency",
            Bound::Compute => "compute",
            Bound::Atomics => "atomics",
        }
    }
}

/// Estimate device throughput for an op class described by `stats`.
pub fn estimate(spec: &DeviceSpec, residency: Residency, stats: &OpStats) -> ThroughputEstimate {
    let (bw_gbs, latency_ns) = match residency {
        Residency::L2 => (spec.l2_bw_gbs, spec.l2_latency_ns),
        Residency::Dram => (spec.dram_bw_gbs, spec.dram_latency_ns),
    };

    // 1. Bandwidth term: sectors × 32 B per op.
    let bytes_per_op = stats.sectors_per_op.max(0.25) * 32.0;
    let bw_limit = bw_gbs * 1e9 / bytes_per_op;

    // 2. Latency × concurrency: each op is a chain of `serial_deps`
    //    dependent round trips; the device keeps `max_inflight` chains
    //    going at once.
    let chain_ns = stats.serial_deps.max(1.0) * latency_ns;
    let lat_limit = spec.max_inflight / (chain_ns * 1e-9);

    // 3. Compute.
    let comp_limit = spec.compute_gops * 1e9 / stats.compute_ops.max(1.0);

    // 4. Atomics, derated by retry traffic.
    let eff_atomics = stats.atomics_per_op * (1.0 + stats.atomic_retry_frac);
    let atomic_limit = if eff_atomics <= 0.0 {
        f64::INFINITY
    } else {
        spec.atomic_gops * 1e9 / eff_atomics
    };

    let (mut best, mut bound) = (bw_limit, Bound::Bandwidth);
    for (v, b) in [
        (lat_limit, Bound::Latency),
        (comp_limit, Bound::Compute),
        (atomic_limit, Bound::Atomics),
    ] {
        if v < best {
            best = v;
            bound = b;
        }
    }
    ThroughputEstimate {
        b_ops: best / 1e9,
        bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::{GH200, RTX_PRO_6000};

    fn simple_stats() -> OpStats {
        OpStats {
            sectors_per_op: 2.0,
            serial_deps: 1.0,
            compute_ops: 40.0,
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }
    }

    #[test]
    fn bandwidth_bound_in_dram() {
        let e = estimate(&GH200, Residency::Dram, &simple_stats());
        // 3.4 TB/s ÷ 64 B/op ≈ 53 B ops/s.
        assert!(e.b_ops > 30.0 && e.b_ops < 60.0, "{e:?}");
        assert_eq!(e.bound, Bound::Bandwidth);
    }

    #[test]
    fn hbm_beats_gddr_when_bandwidth_bound() {
        let s = simple_stats();
        let h = estimate(&GH200, Residency::Dram, &s);
        let g = estimate(&RTX_PRO_6000, Residency::Dram, &s);
        assert!(h.b_ops > g.b_ops * 1.5, "HBM3 should lead: {h:?} vs {g:?}");
    }

    #[test]
    fn long_chains_become_latency_bound() {
        let mut s = simple_stats();
        s.serial_deps = 20.0; // deep eviction chain / run shifting
        let e = estimate(&GH200, Residency::Dram, &s);
        assert_eq!(e.bound, Bound::Latency);
        let short = estimate(&GH200, Residency::Dram, &simple_stats());
        assert!(e.b_ops < short.b_ops / 4.0);
    }

    #[test]
    fn compute_heavy_ops_bound_by_compute_in_l2() {
        let mut s = simple_stats();
        s.compute_ops = 4000.0; // TCF-style cooperative sorting
        let e = estimate(&GH200, Residency::L2, &s);
        assert_eq!(e.bound, Bound::Compute);
        // The RTX (more SMs × higher clock) should pull ahead on a
        // compute-bound op — the paper's System A vs B contrast.
        let g = estimate(&RTX_PRO_6000, Residency::L2, &s);
        assert!(g.b_ops > e.b_ops);
    }

    #[test]
    fn l2_faster_than_dram() {
        let s = simple_stats();
        let l2 = estimate(&GH200, Residency::L2, &s);
        let dram = estimate(&GH200, Residency::Dram, &s);
        assert!(l2.b_ops > dram.b_ops);
    }

    #[test]
    fn from_trace_conversion() {
        use crate::filter::probe::Probe as _;
        let mut t = crate::filter::TraceProbe::new();
        for i in 0..100 {
            t.read(i * 8); // distinct sectors
            t.atomic(i * 8, i % 10 != 0);
            t.evictions((i % 3 == 0) as u32);
        }
        let s = OpStats::from_trace(&t, 100);
        assert!((s.sectors_per_op - 1.0).abs() < 1e-9);
        assert!((s.atomics_per_op - 1.0).abs() < 1e-9);
        assert!((s.atomic_retry_frac - 0.1).abs() < 1e-9);
        assert!(s.serial_deps > 1.0);
    }
}
