//! Analytic per-filter access models.
//!
//! Each function returns the [`OpStats`] of one operation class at target
//! load factor `alpha`, derived from the structure's algorithm (sectors
//! per op, dependent-chain depth, compute weight, atomics). Constants are
//! first-order estimates documented inline and calibrated so the model
//! reproduces the *shape* of the paper's Figure 3 (who wins, rough
//! factors, L2-vs-DRAM flips); EXPERIMENTS.md reports model-vs-paper
//! ratios side by side. The cuckoo filter's stats can alternatively be
//! *measured* from real traces via [`OpStats::from_trace`], which the
//! Figure-3 harness does.
//!
//! Compute weights are in scalar-op equivalents including loop and
//! address-generation overhead (~300 for a hash + one-bucket SWAR probe).
//!
//! An optional `concurrency_cap` models structures whose synchronisation
//! limits parallelism below the device's memory-level parallelism
//! (GQF region locks, TCF cooperative-group serialisation).

use super::model::{OpClass, OpStats};

/// Extended stats with a concurrency cap (see [`estimate_capped`]).
#[derive(Clone, Copy, Debug)]
pub struct FilterOpModel {
    pub stats: OpStats,
    /// Max concurrent chains the structure's synchronisation allows
    /// (f64::INFINITY = device-limited only).
    pub concurrency_cap: f64,
}

/// Estimate with the structure's own concurrency cap applied.
pub fn estimate_capped(
    spec: &super::DeviceSpec,
    residency: super::Residency,
    m: &FilterOpModel,
) -> super::ThroughputEstimate {
    let mut spec = *spec;
    spec.max_inflight = spec.max_inflight.min(m.concurrency_cap);
    super::estimate(&spec, residency, &m.stats)
}

fn uncapped(stats: OpStats) -> FilterOpModel {
    FilterOpModel {
        stats,
        concurrency_cap: f64::INFINITY,
    }
}

/// Cuckoo-GPU (this paper): fp16, b=16 → one 32 B sector per bucket.
///
/// * insert: the batch fills the table from empty to α, so chain/atomic
///   costs are evaluated at the *mean* load of the fill (≈0.8 α weighted
///   toward the expensive tail);
/// * query+: resolves in the first bucket most of the time ("a positive
///   query can often finish after a single memory transaction") — ~1.2
///   sectors;
/// * query−: always both buckets + full SWAR scan — the compute-heavier
///   path the paper calls out;
/// * delete: SWAR match + one CAS.
pub fn cuckoo(op: OpClass, alpha: f64, bfs: bool) -> FilterOpModel {
    let fill_mean = 0.8 * alpha; // average load over the fill
    let chain = eviction_chain_mean(fill_mean, bfs);
    match op {
        OpClass::Insert => uncapped(OpStats {
            // ~1.3 bucket reads for the direct try; each eviction step
            // rereads a bucket; BFS adds candidate probes (independent
            // reads → bandwidth, not latency).
            sectors_per_op: 1.3 + chain * if bfs { 3.0 } else { 1.0 },
            serial_deps: 1.0 + chain,
            compute_ops: 400.0 + 150.0 * chain,
            atomics_per_op: 1.0 + chain,
            atomic_retry_frac: 0.02 + 0.08 * chain.min(1.0),
        }),
        OpClass::QueryPositive => uncapped(OpStats {
            sectors_per_op: 1.2, // mostly one transaction
            serial_deps: 1.0,
            compute_ops: 300.0,
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }),
        OpClass::QueryNegative => uncapped(OpStats {
            sectors_per_op: 2.0, // both buckets, full scan
            serial_deps: 1.0,
            compute_ops: 600.0, // the SWAR arithmetic the paper calls out
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }),
        OpClass::Delete => uncapped(OpStats {
            sectors_per_op: 1.5,
            serial_deps: 1.0,
            compute_ops: 350.0,
            atomics_per_op: 1.0,
            atomic_retry_frac: 0.02,
        }),
    }
}

/// Mean eviction-chain length per insert at load α.
/// Classic cuckoo DFS chains blow up near capacity; the BFS heuristic
/// bounds the *serial* depth by resolving most evictions in one hop.
pub fn eviction_chain_mean(alpha: f64, bfs: bool) -> f64 {
    let a = alpha.clamp(0.0, 0.99);
    // P(both candidate buckets full) rises sharply near 1; conditioned on
    // eviction the DFS chain is ~1/(1-a).
    let p_evict = a.powf(8.0);
    if bfs {
        // BFS resolves almost all evictions in one two-step relocation.
        p_evict * (1.0 + a * a)
    } else {
        p_evict / (1.0 - a)
    }
}

/// GPU Blocked Bloom filter (cuCollections-style): one 32 B block
/// (1 sector) per op, K probe bits computed and tested per op, no
/// dependent chain; insert = a couple of coalesced atomic ORs.
pub fn bbf(op: OpClass, _alpha: f64) -> FilterOpModel {
    match op {
        OpClass::Insert => uncapped(OpStats {
            sectors_per_op: 1.0,
            serial_deps: 1.0,
            compute_ops: 420.0, // k probe-position computations + ORs
            atomics_per_op: 0.6, // fetch_or, heavily coalesced
            atomic_retry_frac: 0.0,
        }),
        // Positive and negative queries read the whole block either way.
        OpClass::QueryPositive | OpClass::QueryNegative => uncapped(OpStats {
            sectors_per_op: 1.0,
            serial_deps: 1.0,
            compute_ops: 330.0,
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }),
        OpClass::Delete => uncapped(OpStats {
            // Unsupported; modelled as free (excluded from plots).
            sectors_per_op: 0.0,
            serial_deps: 1.0,
            compute_ops: 1.0,
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }),
    }
}

/// Two-Choice filter: cooperative groups load and *sort* both candidate
/// buckets in shared memory per mutation — heavy compute + intra-warp
/// synchronisation ("massive compute and intra-warp synchronisation
/// overheads", §3). Queries also pay the cooperative load+scan.
pub fn tcf(op: OpClass, alpha: f64) -> FilterOpModel {
    let sort_cost = 12_000.0; // block sort + group barriers, scalar-op equiv
    match op {
        OpClass::Insert => FilterOpModel {
            stats: OpStats {
                sectors_per_op: 4.0, // both buckets fully, occupancy pass
                serial_deps: 2.0,    // load → sort → writeback
                compute_ops: sort_cost,
                atomics_per_op: 2.0 + alpha,
                atomic_retry_frac: 0.05,
            },
            // Cooperative rewrite serialises per bucket pair.
            concurrency_cap: 3000.0,
        },
        OpClass::QueryPositive | OpClass::QueryNegative => FilterOpModel {
            stats: OpStats {
                sectors_per_op: 4.0,
                serial_deps: 1.5,
                compute_ops: sort_cost * 0.6,
                atomics_per_op: 0.0,
                atomic_retry_frac: 0.0,
            },
            concurrency_cap: f64::INFINITY,
        },
        OpClass::Delete => FilterOpModel {
            stats: OpStats {
                sectors_per_op: 4.0,
                serial_deps: 2.0,
                compute_ops: sort_cost,
                atomics_per_op: 2.0,
                atomic_retry_frac: 0.05,
            },
            // Deletion rewrites the sorted block under group
            // synchronisation — the paper measures it 107× slower than
            // cuckoo in L2.
            concurrency_cap: 300.0,
        },
    }
}

/// GPU counting Quotient filter: Robin-Hood shifting of sorted runs.
/// Inserts/deletes shift `O(cluster)` slots *serially* while holding a
/// region lock — strictly serial dependencies ("fundamentally
/// latency-bound"). Queries rank/select then walk the run.
pub fn gqf(op: OpClass, alpha: f64, table_slots: usize) -> FilterOpModel {
    let a = alpha.clamp(0.0, 0.98);
    // Expected cluster length for Robin-Hood at load a grows ~1/(1-a).
    let cluster = (1.0 / (1.0 - a)).min(40.0);
    // One lock region per 2^14 slots; even-odd scheme → half active.
    let regions = ((table_slots >> 14).max(1) as f64 / 2.0).max(1.0);
    match op {
        OpClass::Insert | OpClass::Delete => FilterOpModel {
            stats: OpStats {
                sectors_per_op: 1.0 + cluster / 8.0, // runs are contiguous
                serial_deps: 1.0 + cluster,          // shift one slot at a time
                compute_ops: 200.0 + 40.0 * cluster,
                atomics_per_op: 2.0 + cluster / 2.0,
                atomic_retry_frac: 0.1,
            },
            concurrency_cap: regions,
        },
        OpClass::QueryPositive | OpClass::QueryNegative => uncapped(OpStats {
            sectors_per_op: 1.0 + cluster / 16.0,
            serial_deps: 1.0 + cluster / 2.0, // decode metadata, walk run
            compute_ops: 300.0 + 30.0 * cluster,
            atomics_per_op: 0.0,
            atomic_retry_frac: 0.0,
        }),
    }
}

/// Bucketed cuckoo hash table with full 64-bit keys: identical algorithm
/// shape to the cuckoo filter but 4× the bytes per bucket (16 slots ×
/// 8 B = 128 B = 4 sectors) and uncoalescable full-word CAS.
pub fn bcht(op: OpClass, alpha: f64) -> FilterOpModel {
    let base = cuckoo(op, alpha, false).stats;
    uncapped(OpStats {
        sectors_per_op: base.sectors_per_op * 4.0,
        serial_deps: base.serial_deps,
        compute_ops: base.compute_ops * 1.5,
        atomics_per_op: base.atomics_per_op * 2.0,
        atomic_retry_frac: base.atomic_retry_frac,
    })
}

/// Partitioned CPU cuckoo filter on the Xeon: same algorithm, but each op
/// is a locked critical section on one partition; 120 threads over the
/// partition set.
pub fn pcf(op: OpClass, alpha: f64) -> FilterOpModel {
    let base = cuckoo(op, alpha, false).stats;
    FilterOpModel {
        stats: OpStats {
            sectors_per_op: base.sectors_per_op,
            serial_deps: base.serial_deps + 1.0, // lock acquire/release
            compute_ops: base.compute_ops,
            atomics_per_op: base.atomics_per_op + 2.0, // lock RMWs
            atomic_retry_frac: 0.05,
        },
        concurrency_cap: 120.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::model::Residency;
    use crate::gpusim::spec::{GH200, RTX_PRO_6000, XEON_W9_DDR5};

    const A: f64 = 0.95;

    fn tput(spec: &crate::gpusim::DeviceSpec, res: Residency, m: &FilterOpModel) -> f64 {
        estimate_capped(spec, res, m).b_ops
    }

    #[test]
    fn cuckoo_dominates_dynamic_filters_everywhere() {
        // The headline ordering of Figure 3: cuckoo > TCF, GQF for all
        // ops, both residencies, both GPUs.
        for spec in [&GH200, &RTX_PRO_6000] {
            for res in [Residency::L2, Residency::Dram] {
                let slots = match res {
                    Residency::L2 => 1 << 22,
                    Residency::Dram => 1 << 28,
                };
                for op in [
                    OpClass::Insert,
                    OpClass::QueryPositive,
                    OpClass::QueryNegative,
                    OpClass::Delete,
                ] {
                    let c = tput(spec, res, &cuckoo(op, A, true));
                    let t = tput(spec, res, &tcf(op, A));
                    let g = tput(spec, res, &gqf(op, A, slots));
                    assert!(c > t, "{} {res:?} {op:?}: cuckoo {c} <= tcf {t}", spec.name);
                    assert!(c > g, "{} {res:?} {op:?}: cuckoo {c} <= gqf {g}", spec.name);
                }
            }
        }
    }

    #[test]
    fn gqf_insert_gap_is_orders_of_magnitude_in_l2() {
        // Paper: 378× on System B, L2-resident inserts.
        let c = tput(&GH200, Residency::L2, &cuckoo(OpClass::Insert, A, true));
        let g = tput(&GH200, Residency::L2, &gqf(OpClass::Insert, A, 1 << 22));
        let ratio = c / g;
        assert!(ratio > 50.0, "L2 insert cuckoo/gqf = {ratio}");
    }

    #[test]
    fn bbf_insert_leads_cuckoo_in_dram() {
        // Paper: cuckoo trails GBBF on DRAM inserts (0.71× on B).
        let c = tput(&GH200, Residency::Dram, &cuckoo(OpClass::Insert, A, true));
        let b = tput(&GH200, Residency::Dram, &bbf(OpClass::Insert, A));
        assert!(b > c, "bbf {b} should lead cuckoo {c}");
        assert!(c / b > 0.4, "cuckoo shouldn't collapse: {}", c / b);
    }

    #[test]
    fn cuckoo_positive_query_rivals_bbf() {
        // Paper: 1.25× GBBF in L2, 0.90× in DRAM on System B.
        let l2c = tput(&GH200, Residency::L2, &cuckoo(OpClass::QueryPositive, A, true));
        let l2b = tput(&GH200, Residency::L2, &bbf(OpClass::QueryPositive, A));
        assert!(l2c >= l2b, "L2 positive query: cuckoo {l2c} vs bbf {l2b}");
        let dc = tput(&GH200, Residency::Dram, &cuckoo(OpClass::QueryPositive, A, true));
        let db = tput(&GH200, Residency::Dram, &bbf(OpClass::QueryPositive, A));
        let r = dc / db;
        assert!(r > 0.7 && r <= 1.05, "DRAM positive query ratio {r}");
    }

    #[test]
    fn negative_queries_cost_more_in_dram() {
        let p = tput(&GH200, Residency::Dram, &cuckoo(OpClass::QueryPositive, A, true));
        let n = tput(&GH200, Residency::Dram, &cuckoo(OpClass::QueryNegative, A, true));
        let r = n / p;
        assert!(r > 0.4 && r < 0.8, "neg/pos = {r} (paper: ≈0.5)");
    }

    #[test]
    fn hbm_advantage_shows_for_cuckoo_not_tcf() {
        // Paper: "our Cuckoo filter does a much better job at utilising
        // the massive HBM3 bandwidth, whereas TCF and GQF stagnate".
        let c_h = tput(&GH200, Residency::Dram, &cuckoo(OpClass::Insert, A, true));
        let c_g = tput(&RTX_PRO_6000, Residency::Dram, &cuckoo(OpClass::Insert, A, true));
        let t_h = tput(&GH200, Residency::Dram, &tcf(OpClass::Insert, A));
        let t_g = tput(&RTX_PRO_6000, Residency::Dram, &tcf(OpClass::Insert, A));
        let cuckoo_scaling = c_h / c_g;
        let tcf_scaling = t_h / t_g;
        assert!(
            cuckoo_scaling > tcf_scaling,
            "cuckoo HBM scaling {cuckoo_scaling} vs tcf {tcf_scaling}"
        );
    }

    #[test]
    fn pcf_on_xeon_is_far_slower() {
        // Paper: 32×–350× speedup over the CPU PCF; the largest gap is
        // L2-resident positive queries.
        let gpu = tput(&GH200, Residency::L2, &cuckoo(OpClass::QueryPositive, A, true));
        let cpu = tput(&XEON_W9_DDR5, Residency::L2, &pcf(OpClass::QueryPositive, A));
        let ratio = gpu / cpu;
        assert!(ratio > 30.0, "gpu/cpu = {ratio}");
    }

    #[test]
    fn bcht_pays_for_full_keys() {
        // Paper: 8.5×–41× slower than the filter across ops on System B.
        for op in [OpClass::Insert, OpClass::QueryPositive, OpClass::Delete] {
            let c = tput(&GH200, Residency::Dram, &cuckoo(op, A, true));
            let b = tput(&GH200, Residency::Dram, &bcht(op, A));
            assert!(c / b >= 2.0, "{op:?}: cuckoo/bcht = {}", c / b);
        }
    }

    #[test]
    fn tcf_gaps_roughly_match_paper_bands() {
        // L2 query: paper 34.7×; we accept anything in [5, 100].
        let c = tput(&GH200, Residency::L2, &cuckoo(OpClass::QueryPositive, A, true));
        let t = tput(&GH200, Residency::L2, &tcf(OpClass::QueryPositive, A));
        let r = c / t;
        assert!((5.0..100.0).contains(&r), "L2 query cuckoo/tcf = {r}");
        // L2 delete: paper 107×; accept [10, 500].
        let cd = tput(&GH200, Residency::L2, &cuckoo(OpClass::Delete, A, true));
        let td = tput(&GH200, Residency::L2, &tcf(OpClass::Delete, A));
        let rd = cd / td;
        assert!((10.0..500.0).contains(&rd), "L2 delete cuckoo/tcf = {rd}");
    }

    #[test]
    fn bfs_chain_shorter_than_dfs_at_high_load() {
        for alpha in [0.90, 0.95, 0.97] {
            assert!(eviction_chain_mean(alpha, true) < eviction_chain_mean(alpha, false));
        }
        // And similar at low load.
        let lo_b = eviction_chain_mean(0.5, true);
        let lo_d = eviction_chain_mean(0.5, false);
        assert!((lo_b - lo_d).abs() < 0.1);
    }

    #[test]
    fn bfs_insert_beats_dfs_at_high_load_dram() {
        // Figure 6's claim: BFS up to ~25% faster at very high load.
        let b = tput(&GH200, Residency::Dram, &cuckoo(OpClass::Insert, 0.98, true));
        let d = tput(&GH200, Residency::Dram, &cuckoo(OpClass::Insert, 0.98, false));
        assert!(b > d, "bfs {b} <= dfs {d}");
    }
}
