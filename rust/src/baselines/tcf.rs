//! Bulk Two-Choice Filter (TCF) baseline — McCoy, Hofmeyr, Yelick &
//! Pandey (PPoPP'23), the paper's main dynamic GPU competitor (§3, §5.1).
//!
//! The TCF eliminates cuckoo-style eviction chains with the
//! power-of-two-choices paradigm: a key has two candidate buckets and is
//! placed in the *less loaded* one; if both are full it overflows into a
//! small secondary stash. The original uses CUDA cooperative groups to
//! load, sort and rewrite whole buckets in shared memory — the compute
//! and intra-warp synchronisation overhead the paper blames for its
//! stagnation on HBM3. We preserve that character: every insert reads
//! both buckets in full (the occupancy comparison), and bucket
//! mutations go through a per-bucket CAS loop over whole words.
//!
//! Layout: like the cuckoo table, fingerprints are packed into u64 words
//! (16-bit tags, 16-slot buckets by default).

use super::common::AmqFilter;
use crate::filter::hash::{xxhash64_u64, DEFAULT_SEED};
use crate::filter::swar::{first_lane, Fp16, Layout};
use crate::filter::table::Table;
use std::sync::Mutex;

/// Stash capacity relative to the primary table (the TCF paper sizes the
/// stash at a small constant fraction).
const STASH_FRACTION: f64 = 0.01;

pub struct TwoChoiceFilter {
    table: Table,
    num_buckets: usize,
    #[allow(dead_code)] // geometry record, reported via bytes()
    bucket_slots: usize,
    seed: u64,
    /// Overflow stash: a locked vector of full fingerprints (the GPU
    /// version uses a cooperative hash table; a lock here is faithful to
    /// its serialisation behaviour under contention).
    stash: Mutex<Vec<u64>>,
    stash_cap: usize,
}

type L = Fp16;

impl TwoChoiceFilter {
    pub fn with_capacity(capacity: usize) -> Self {
        let slots = (capacity as f64 / 0.90).ceil() as usize; // TCF targets ~90%
        let bucket_slots = 16usize;
        let num_buckets = slots.div_ceil(bucket_slots).next_power_of_two().max(2);
        Self::new(num_buckets, bucket_slots)
    }

    pub fn new(num_buckets: usize, bucket_slots: usize) -> Self {
        assert!(num_buckets.is_power_of_two());
        let words_per_bucket = bucket_slots / L::TAGS_PER_WORD as usize;
        let stash_cap =
            ((num_buckets * bucket_slots) as f64 * STASH_FRACTION).ceil() as usize + 16;
        Self {
            table: Table::new(num_buckets, words_per_bucket),
            num_buckets,
            bucket_slots,
            seed: DEFAULT_SEED,
            stash: Mutex::new(Vec::new()),
            stash_cap,
        }
    }

    /// Two independent bucket choices + tag. Unlike partial-key cuckoo
    /// hashing the two indices are unrelated (no relocation ever happens),
    /// and the stored tag identifies the key in either bucket or stash.
    ///
    /// The TCF's 16-bit slots are not all fingerprint: the PPoPP'23
    /// design spends slot bits on metadata/counters, leaving ~12
    /// discriminative bits — which is why the paper measures its FPR an
    /// order of magnitude above the cuckoo filter's (Figure 4,
    /// 0.35%–0.55%). We reproduce that: 13-bit effective tags in 16-bit
    /// lanes (2·b·α·2^-13 ≈ 0.35%).
    #[inline(always)]
    fn plan(&self, key: u64) -> (usize, usize, u64) {
        let h = xxhash64_u64(key, self.seed);
        let mask = (self.num_buckets - 1) as u64;
        let b1 = (h & mask) as usize;
        let b2 = ((h >> 21) & mask) as usize;
        let mut tag = (h >> 48) & 0x1FFF;
        tag += (tag == 0) as u64;
        (b1, b2, tag)
    }

    /// Full-bucket occupancy scan — the cooperative-group load the real
    /// TCF performs per op.
    #[inline]
    fn occupancy(&self, bucket: usize) -> u32 {
        let mut occ = 0;
        for w in 0..self.table.words_per_bucket {
            occ += L::count_occupied(self.table.load(self.table.word_index(bucket, w)));
        }
        occ
    }

    fn try_insert_bucket(&self, bucket: usize, tag: u64) -> bool {
        for w in 0..self.table.words_per_bucket {
            let idx = self.table.word_index(bucket, w);
            let mut word = self.table.load_acquire(idx);
            let mut mask = L::zero_mask(word);
            while mask != 0 {
                let lane = first_lane::<L>(mask);
                match self.table.cas(idx, word, L::replace(word, lane, tag)) {
                    Ok(()) => return true,
                    Err(cur) => {
                        word = cur;
                        mask = L::zero_mask(word);
                    }
                }
            }
        }
        false
    }

    fn bucket_contains(&self, bucket: usize, tag: u64) -> bool {
        (0..self.table.words_per_bucket)
            .any(|w| L::contains_tag(self.table.load(self.table.word_index(bucket, w)), tag))
    }

    fn bucket_remove(&self, bucket: usize, tag: u64) -> bool {
        for w in 0..self.table.words_per_bucket {
            let idx = self.table.word_index(bucket, w);
            let mut word = self.table.load_acquire(idx);
            let mut mask = L::match_mask(word, tag);
            while mask != 0 {
                let lane = first_lane::<L>(mask);
                match self.table.cas(idx, word, L::replace(word, lane, 0)) {
                    Ok(()) => return true,
                    Err(cur) => {
                        word = cur;
                        mask = L::match_mask(word, tag);
                    }
                }
            }
        }
        false
    }

    /// Stash key: bucket-qualified tag so different buckets don't alias.
    #[inline(always)]
    fn stash_token(b1: usize, tag: u64) -> u64 {
        ((b1 as u64) << 16) | tag
    }

    pub fn stash_len(&self) -> usize {
        self.stash.lock().unwrap().len()
    }
}

impl AmqFilter for TwoChoiceFilter {
    fn name(&self) -> &'static str {
        "tcf"
    }

    fn insert(&self, key: u64) -> bool {
        let (b1, b2, tag) = self.plan(key);
        // Power of two choices: compare occupancy (two full bucket reads),
        // then insert into the emptier bucket; tie → primary first.
        let (first, second) = if self.occupancy(b1) <= self.occupancy(b2) {
            (b1, b2)
        } else {
            (b2, b1)
        };
        if self.try_insert_bucket(first, tag) || self.try_insert_bucket(second, tag) {
            return true;
        }
        // Overflow → stash.
        let mut stash = self.stash.lock().unwrap();
        if stash.len() >= self.stash_cap {
            return false;
        }
        stash.push(Self::stash_token(b1, tag));
        true
    }

    fn contains(&self, key: u64) -> bool {
        let (b1, b2, tag) = self.plan(key);
        if self.bucket_contains(b1, tag) || self.bucket_contains(b2, tag) {
            return true;
        }
        let tok = Self::stash_token(b1, tag);
        self.stash.lock().unwrap().contains(&tok)
    }

    fn remove(&self, key: u64) -> bool {
        let (b1, b2, tag) = self.plan(key);
        if self.bucket_remove(b1, tag) || self.bucket_remove(b2, tag) {
            return true;
        }
        let tok = Self::stash_token(b1, tag);
        let mut stash = self.stash.lock().unwrap();
        if let Some(pos) = stash.iter().position(|&t| t == tok) {
            stash.swap_remove(pos);
            true
        } else {
            false
        }
    }

    fn bytes(&self) -> usize {
        self.table.bytes() + self.stash_cap * 8
    }

    fn bits_per_entry(&self) -> f64 {
        16.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::mix64;

    fn keys(n: usize, stream: u64) -> Vec<u64> {
        (0..n as u64).map(|i| mix64(i ^ (stream << 44))).collect()
    }

    #[test]
    fn insert_query_delete() {
        let f = TwoChoiceFilter::with_capacity(10_000);
        let ks = keys(10_000, 1);
        for &k in &ks {
            assert!(f.insert(k));
        }
        for &k in &ks {
            assert!(f.contains(k));
        }
        // 13-bit tags collide occasionally: deleting key A may consume
        // key B's matching copy (standard AMQ false-delete semantics), so
        // a handful of removes may miss. Require ≥99.5% success and a
        // near-empty filter afterwards.
        let removed = ks.iter().filter(|&&k| f.remove(k)).count();
        assert!(removed >= 9_950, "removed only {removed}");
        let residue = ks.iter().filter(|&&k| f.contains(k)).count();
        assert!(residue <= 100, "residue {residue}");
    }

    #[test]
    fn overflow_goes_to_stash() {
        // Tiny table to force overflow.
        let f = TwoChoiceFilter::new(2, 16); // 32 slots
        let ks = keys(40, 2);
        let mut ok = 0;
        for &k in &ks {
            if f.insert(k) {
                ok += 1;
            }
        }
        assert!(ok > 32, "stash should absorb some overflow");
        assert!(f.stash_len() > 0);
        // Everything accepted must be findable.
        let found = ks.iter().filter(|&&k| f.contains(k)).count();
        assert!(found >= ok);
    }

    #[test]
    fn balances_load() {
        let f = TwoChoiceFilter::with_capacity(100_000);
        for k in keys(100_000, 3) {
            assert!(f.insert(k));
        }
        // Two-choice placement at 90% target: stash stays small.
        assert!(f.stash_len() < 1000, "stash={}", f.stash_len());
    }

    #[test]
    fn fpr_order_of_magnitude() {
        // Paper Fig. 4: TCF FPR ~0.35%–0.55% (worse than cuckoo fp16
        // because only 16 tag bits minus bucket entropy are discriminative).
        let f = TwoChoiceFilter::with_capacity(200_000);
        for k in keys(200_000, 4) {
            f.insert(k);
        }
        let probes = keys(200_000, 555);
        let fp = probes.iter().filter(|&&k| f.contains(k)).count();
        let fpr = fp as f64 / probes.len() as f64;
        assert!(fpr < 0.02, "fpr={fpr}");
    }

    #[test]
    fn concurrent_batch() {
        use crate::device::Device;
        let f = TwoChoiceFilter::with_capacity(50_000);
        let d = Device::with_workers(8);
        let ks = keys(50_000, 5);
        let ok = super::super::common::run_batch(&f, &d, crate::op::OpKind::Insert, &ks);
        assert_eq!(ok, 50_000);
        assert_eq!(super::super::common::run_batch(&f, &d, crate::op::OpKind::Query, &ks), 50_000);
    }
}
