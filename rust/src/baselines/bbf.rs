//! GPU Blocked Bloom filter (GBBF) baseline — the cuCollections /
//! WarpCore-style structure the paper uses as its append-only
//! high-performance reference (§3, §5.1).
//!
//! Layout: the bit array is partitioned into cache-line-sized blocks
//! (64 B = 512 bits, matching one sector-aligned GPU access). A key maps
//! to exactly one block; `K` probe bits are set inside that block via
//! double hashing. One op therefore touches one block — the cache-local
//! behaviour that makes BBFs fast but also concentrates collisions
//! (the paper's Figure 4 shows its FPR suffering for exactly this
//! reason).

use super::common::AmqFilter;
use crate::filter::hash::{xxhash64_u64, DEFAULT_SEED};
use std::sync::atomic::{AtomicU64, Ordering};

/// Words per block: 8 × u64 = 512 bits = 64 bytes.
const WORDS_PER_BLOCK: usize = 8;
const BLOCK_BITS: u64 = 512;

pub struct BlockedBloomFilter {
    words: Box<[AtomicU64]>,
    num_blocks: usize,
    /// Probe bits per key.
    k: u32,
    seed: u64,
    /// Design bits-per-key, for reporting.
    bits_per_key: f64,
}

impl BlockedBloomFilter {
    /// Build for `capacity` keys at `bits_per_key` total budget
    /// (the paper's synthetic benchmarks use 16 bits per item).
    pub fn with_capacity(capacity: usize, bits_per_key: f64) -> Self {
        let total_bits = (capacity as f64 * bits_per_key).ceil() as usize;
        Self::with_bytes(total_bits.div_ceil(8), bits_per_key)
    }

    /// Build with a fixed memory budget (Figure 4 protocol). `bits_per_key`
    /// only picks K; the block count comes from the budget.
    pub fn with_bytes(bytes: usize, bits_per_key: f64) -> Self {
        let num_blocks = (bytes / 64).max(1);
        // Standard Bloom would use K ≈ ln2 · bits-per-key (≈11 at 16
        // bpk), but blocked GPU filters are *speed*-optimal, not
        // FPR-optimal (Lang et al., "performance-optimal filtering"):
        // cuCollections sets only a few bits within one block per key.
        // K≈3 at 16 bpk reproduces the paper's measured BBF FPR band
        // (0.5%–6%, the worst of all tested filters, Figure 4).
        let k = (bits_per_key * 0.1875).round().clamp(2.0, 16.0) as u32;
        let words: Vec<AtomicU64> = (0..num_blocks * WORDS_PER_BLOCK)
            .map(|_| AtomicU64::new(0))
            .collect();
        Self {
            words: words.into_boxed_slice(),
            num_blocks,
            k,
            seed: DEFAULT_SEED,
            bits_per_key,
        }
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    /// Copy the bit array out (feeds the PJRT bloom-query artifact).
    pub fn snapshot(&self) -> Vec<u64> {
        self.words.iter().map(|w| w.load(Ordering::Relaxed)).collect()
    }

    /// Block index + the two double-hashing increments for a key.
    #[inline(always)]
    fn plan(&self, key: u64) -> (usize, u64, u64) {
        let h = xxhash64_u64(key, self.seed);
        let block = (h % self.num_blocks as u64) as usize;
        // Upper half drives the in-block probe sequence.
        let h1 = h >> 32;
        let h2 = (h >> 17) | 1; // odd increment → full-period probing
        (block, h1, h2)
    }

    /// The i-th probe bit inside the block.
    #[inline(always)]
    fn probe_bit(h1: u64, h2: u64, i: u32) -> u64 {
        h1.wrapping_add(h2.wrapping_mul(i as u64)) % BLOCK_BITS
    }
}

impl AmqFilter for BlockedBloomFilter {
    fn name(&self) -> &'static str {
        "gbbf"
    }

    fn insert(&self, key: u64) -> bool {
        let (block, h1, h2) = self.plan(key);
        let base = block * WORDS_PER_BLOCK;
        // Collect per-word OR masks first (one atomic per touched word,
        // mirroring the warp-cooperative single-transaction update).
        let mut masks = [0u64; WORDS_PER_BLOCK];
        for i in 0..self.k {
            let bit = Self::probe_bit(h1, h2, i);
            masks[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
        for (w, &m) in masks.iter().enumerate() {
            if m != 0 {
                self.words[base + w].fetch_or(m, Ordering::Relaxed);
            }
        }
        true
    }

    fn contains(&self, key: u64) -> bool {
        let (block, h1, h2) = self.plan(key);
        let base = block * WORDS_PER_BLOCK;
        let mut masks = [0u64; WORDS_PER_BLOCK];
        for i in 0..self.k {
            let bit = Self::probe_bit(h1, h2, i);
            masks[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
        for (w, &m) in masks.iter().enumerate() {
            if m != 0 && self.words[base + w].load(Ordering::Relaxed) & m != m {
                return false;
            }
        }
        true
    }

    fn remove(&self, _key: u64) -> bool {
        false // append-only
    }

    fn supports_delete(&self) -> bool {
        false
    }

    fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    fn bits_per_entry(&self) -> f64 {
        self.bits_per_key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::mix64;

    fn keys(n: usize, stream: u64) -> Vec<u64> {
        (0..n as u64).map(|i| mix64(i ^ (stream << 48))).collect()
    }

    #[test]
    fn no_false_negatives() {
        let f = BlockedBloomFilter::with_capacity(10_000, 16.0);
        let ks = keys(10_000, 1);
        for &k in &ks {
            assert!(f.insert(k));
        }
        for &k in &ks {
            assert!(f.contains(k), "false negative {k:#x}");
        }
    }

    #[test]
    fn fpr_reasonable_at_16bpk() {
        let f = BlockedBloomFilter::with_capacity(100_000, 16.0);
        for k in keys(100_000, 2) {
            f.insert(k);
        }
        let probes = keys(100_000, 999);
        let fp = probes.iter().filter(|&&k| f.contains(k)).count();
        let fpr = fp as f64 / probes.len() as f64;
        // Paper's Figure 4: BBF FPR sits in the 0.5%–6% band.
        assert!(fpr < 0.06, "fpr={fpr}");
        assert!(fpr > 0.001, "fpr={fpr} suspiciously low for a blocked bloom");
    }

    #[test]
    fn delete_unsupported() {
        let f = BlockedBloomFilter::with_capacity(10, 16.0);
        f.insert(3);
        assert!(!f.remove(3));
        assert!(!f.supports_delete());
        assert!(f.contains(3));
    }

    #[test]
    fn memory_budget_respected() {
        let f = BlockedBloomFilter::with_bytes(1 << 20, 16.0);
        assert_eq!(f.bytes(), 1 << 20);
    }

    #[test]
    fn one_block_per_op() {
        // All probe bits for one key land in one 512-bit block.
        let f = BlockedBloomFilter::with_capacity(1000, 16.0);
        let (block, h1, h2) = f.plan(0xDEADBEEF);
        for i in 0..f.k() {
            let bit = BlockedBloomFilter::probe_bit(h1, h2, i);
            assert!(bit < BLOCK_BITS);
        }
        assert!(block < f.num_blocks);
    }

    #[test]
    fn concurrent_inserts_dont_lose_bits() {
        use crate::device::Device;
        let f = BlockedBloomFilter::with_capacity(50_000, 16.0);
        let ks = keys(50_000, 3);
        let d = Device::with_workers(8);
        super::super::common::run_batch(&f, &d, crate::op::OpKind::Insert, &ks);
        for &k in &ks {
            assert!(f.contains(k));
        }
    }
}
