//! Bucketed Cuckoo Hash Table (BCHT) baseline — Awad et al. (APOCS'23),
//! included by the paper to show that a full hash table "used as a
//! filter" pays roughly an order of magnitude in memory and bandwidth
//! versus a fingerprint filter (§3, §5.2 "Hash Table and CPU Baseline").
//!
//! Stores *full 64-bit keys* in 16-slot buckets; insertion is a cuckoo
//! random-walk over whole-key slots via 64-bit CAS. Exact membership —
//! zero false positives — but 4× the bytes of a 16-bit-tag filter and
//! therefore 4× the memory traffic per probe.

use super::common::AmqFilter;
use crate::filter::hash::{xxhash64_u64, DEFAULT_SEED};
use crate::util::prng::{mix64, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};

const EMPTY: u64 = 0;
const BUCKET_SLOTS: usize = 16;
const MAX_EVICTIONS: usize = 500;

pub struct BuckCuckooHashTable {
    slots: Box<[AtomicU64]>,
    num_buckets: usize,
    seed: u64,
}

impl BuckCuckooHashTable {
    pub fn with_capacity(capacity: usize) -> Self {
        let slots_needed = (capacity as f64 / 0.90).ceil() as usize;
        let num_buckets = slots_needed.div_ceil(BUCKET_SLOTS).next_power_of_two().max(2);
        let slots: Vec<AtomicU64> = (0..num_buckets * BUCKET_SLOTS)
            .map(|_| AtomicU64::new(EMPTY))
            .collect();
        Self {
            slots: slots.into_boxed_slice(),
            num_buckets,
            seed: DEFAULT_SEED,
        }
    }

    /// Keys are stored transformed so the EMPTY sentinel (0) never
    /// collides with a real key: store mix64(key) which is a bijection,
    /// remapping the single key that hits 0.
    #[inline(always)]
    fn encode(key: u64) -> u64 {
        let e = mix64(key);
        e + (e == EMPTY) as u64
    }

    #[inline(always)]
    fn bucket_pair(&self, encoded: u64) -> (usize, usize) {
        let h = xxhash64_u64(encoded, self.seed);
        let mask = (self.num_buckets - 1) as u64;
        let b1 = (h & mask) as usize;
        let b2 = (b1 as u64 ^ (mix64(h >> 32 | 1).max(1) & mask)) as usize;
        (b1, b2)
    }

    fn try_insert_bucket(&self, bucket: usize, encoded: u64) -> bool {
        let base = bucket * BUCKET_SLOTS;
        for s in 0..BUCKET_SLOTS {
            let slot = &self.slots[base + s];
            let mut cur = slot.load(Ordering::Acquire);
            while cur == EMPTY {
                match slot.compare_exchange(EMPTY, encoded, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => return true,
                    Err(now) => cur = now,
                }
            }
        }
        false
    }

    fn bucket_contains(&self, bucket: usize, encoded: u64) -> bool {
        let base = bucket * BUCKET_SLOTS;
        (0..BUCKET_SLOTS).any(|s| self.slots[base + s].load(Ordering::Relaxed) == encoded)
    }

    fn bucket_remove(&self, bucket: usize, encoded: u64) -> bool {
        let base = bucket * BUCKET_SLOTS;
        for s in 0..BUCKET_SLOTS {
            let slot = &self.slots[base + s];
            let mut cur = slot.load(Ordering::Acquire);
            while cur == encoded {
                match slot.compare_exchange(encoded, EMPTY, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => return true,
                    Err(now) => cur = now,
                }
            }
        }
        false
    }
}

impl AmqFilter for BuckCuckooHashTable {
    fn name(&self) -> &'static str {
        "bcht"
    }

    fn insert(&self, key: u64) -> bool {
        let mut enc = Self::encode(key);
        let (b1, b2) = self.bucket_pair(enc);
        if self.try_insert_bucket(b1, enc) || self.try_insert_bucket(b2, enc) {
            return true;
        }
        // Cuckoo random walk over full keys.
        let mut rng = SplitMix64::new(enc ^ 0x1234_5678_9ABC_DEF0);
        let mut bucket = if rng.next_u64() & 1 == 0 { b1 } else { b2 };
        for _ in 0..MAX_EVICTIONS {
            let s = rng.next_below(BUCKET_SLOTS as u64) as usize;
            let slot = &self.slots[bucket * BUCKET_SLOTS + s];
            // Swap our key with the victim.
            let mut victim = slot.load(Ordering::Acquire);
            loop {
                match slot.compare_exchange(victim, enc, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => break,
                    Err(now) => victim = now,
                }
            }
            if victim == EMPTY {
                return true;
            }
            // Victim moves to its other bucket.
            let (v1, v2) = self.bucket_pair(victim);
            let next = if v1 == bucket { v2 } else { v1 };
            if self.try_insert_bucket(next, victim) {
                return true;
            }
            enc = victim;
            bucket = next;
        }
        false
    }

    fn contains(&self, key: u64) -> bool {
        let enc = Self::encode(key);
        let (b1, b2) = self.bucket_pair(enc);
        self.bucket_contains(b1, enc) || self.bucket_contains(b2, enc)
    }

    fn remove(&self, key: u64) -> bool {
        let enc = Self::encode(key);
        let (b1, b2) = self.bucket_pair(enc);
        self.bucket_remove(b1, enc) || self.bucket_remove(b2, enc)
    }

    fn bytes(&self) -> usize {
        self.slots.len() * 8
    }

    fn bits_per_entry(&self) -> f64 {
        64.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::mix64 as mx;

    fn keys(n: usize, stream: u64) -> Vec<u64> {
        (0..n as u64).map(|i| mx(i ^ (stream << 36))).collect()
    }

    #[test]
    fn exact_membership() {
        let t = BuckCuckooHashTable::with_capacity(10_000);
        let ks = keys(10_000, 1);
        for &k in &ks {
            assert!(t.insert(k));
        }
        for &k in &ks {
            assert!(t.contains(k));
        }
        // Zero false positives — it stores full keys.
        for k in keys(50_000, 999) {
            assert!(!t.contains(k));
        }
    }

    #[test]
    fn delete_exact() {
        let t = BuckCuckooHashTable::with_capacity(1000);
        let ks = keys(1000, 2);
        for &k in &ks {
            t.insert(k);
        }
        for &k in &ks {
            assert!(t.remove(k));
            assert!(!t.contains(k));
        }
    }

    #[test]
    fn memory_is_4x_of_fp16_filter() {
        let t = BuckCuckooHashTable::with_capacity(100_000);
        let f =
            crate::filter::CuckooFilter::<crate::filter::Fp16>::new(
                crate::filter::CuckooConfig::with_capacity(100_000),
            )
            .unwrap();
        let ratio = t.bytes() as f64 / crate::filter::CuckooFilter::bytes(&f) as f64;
        assert!(ratio >= 3.0, "BCHT/cuckoo byte ratio = {ratio}");
    }

    #[test]
    fn key_zero_and_friends() {
        let t = BuckCuckooHashTable::with_capacity(100);
        for k in [0u64, 1, u64::MAX] {
            assert!(t.insert(k));
            assert!(t.contains(k));
        }
        assert!(t.remove(0));
        assert!(!t.contains(0));
        assert!(t.contains(1));
    }

    #[test]
    fn concurrent_fill() {
        use crate::device::Device;
        let t = BuckCuckooHashTable::with_capacity(50_000);
        let d = Device::with_workers(8);
        let ks = keys(50_000, 3);
        let ok = super::super::common::run_batch(&t, &d, crate::op::OpKind::Insert, &ks);
        assert_eq!(ok, 50_000);
        assert_eq!(super::super::common::run_batch(&t, &d, crate::op::OpKind::Query, &ks), 50_000);
    }
}
