//! Reimplementations of the paper's five comparison structures (§5.1):
//!
//! | Paper baseline | Module | Character preserved |
//! |---|---|---|
//! | GPU Blocked Bloom filter (cuCollections/WarpCore) | [`bbf`] | append-only, one-block access per op |
//! | Bulk Two-Choice filter (McCoy et al.) | [`tcf`] | power-of-two-choices + overflow stash, per-op occupancy comparison |
//! | GPU Counting Quotient filter | [`gqf`]  | Robin-Hood shifting of sorted runs → serial dependencies |
//! | Bucketed Cuckoo Hash Table (Awad et al.) | [`bcht`] | full 64-bit keys → ~4× the memory traffic |
//! | Partitioned CPU Cuckoo filter (Schmidt et al.) | [`pcf`] | classic b=4 CPU layout behind partition locks |
//!
//! All implement [`AmqFilter`], so the benchmark harness treats them and
//! [`crate::filter::CuckooFilter`] uniformly.

pub mod common;
pub mod bbf;
pub mod tcf;
pub mod gqf;
pub mod bcht;
pub mod pcf;

pub use bbf::BlockedBloomFilter;
pub use bcht::BuckCuckooHashTable;
pub use common::{empirical_fpr, run_batch, AmqFilter};
pub use gqf::QuotientFilter;
pub use pcf::PartitionedCuckooFilter;
pub use tcf::TwoChoiceFilter;
