//! GPU Counting Quotient Filter (GQF) baseline (§3, §5.1).
//!
//! The GQF of McCoy et al. stores r-bit remainders in sorted, contiguous
//! *runs* (one per quotient) using Robin-Hood hashing; keeping runs
//! contiguous requires shifting elements on every insert/delete, which
//! creates the strict serial dependencies that make it latency-bound —
//! the very property the paper's evaluation highlights.
//!
//! This implementation is the classic three-metadata-bit quotient filter
//! (Bender et al., "Don't Thrash") — `is_occupied`, `is_continuation`,
//! `is_shifted` per slot — which exhibits the same shifting behaviour as
//! the rank-and-select CQF the GPU code uses. Concurrency follows the
//! GQF's region-locking idea ("even-odd" lock-free regions): the filter
//! is sharded by the upper hash bits into independent regions, each a
//! complete quotient filter behind its own lock; operations serialise
//! within a region and run concurrently across regions.
//!
//! Mutations rebuild the affected *supercluster* (the contiguous occupied
//! span bounded by empty slots) — O(cluster) work exactly like textbook
//! shifting, with far less edge-case surface. Duplicates are stored as
//! repeated remainders in the run (counting via repetition).

use super::common::AmqFilter;
use crate::filter::hash::{xxhash64_u64, DEFAULT_SEED};
use std::sync::Mutex;

const OCCUPIED: u64 = 1 << 0;
const CONTINUATION: u64 = 1 << 1;
const SHIFTED: u64 = 1 << 2;
const META_MASK: u64 = 0b111;

/// One independent quotient-filter region.
struct Region {
    /// Slot words: bits [3, 3+r) = remainder, bits [0,3) = metadata.
    slots: Vec<u64>,
    q_bits: u32,
    len: usize,
    cap: usize,
}

impl Region {
    fn new(q_bits: u32) -> Self {
        let n = 1usize << q_bits;
        Self {
            slots: vec![0; n],
            q_bits,
            len: 0,
            cap: (n as f64 * 0.95) as usize,
        }
    }

    #[inline(always)]
    fn size(&self) -> usize {
        1 << self.q_bits
    }

    #[inline(always)]
    fn rem_of(&self, slot: u64) -> u64 {
        slot >> 3
    }

    #[inline(always)]
    fn make_slot(&self, rem: u64, meta: u64) -> u64 {
        (rem << 3) | meta
    }

    #[inline(always)]
    fn idx(&self, i: isize) -> usize {
        i.rem_euclid(self.size() as isize) as usize
    }

    #[inline(always)]
    fn is_empty_slot(&self, i: usize) -> bool {
        // A filled slot always carries metadata: a home run-start has its
        // own quotient's OCCUPIED bit on the same slot, any other element
        // has CONTINUATION and/or SHIFTED set.
        self.slots[i] & META_MASK == 0
    }

    /// Does this slot hold an element? (OCCUPIED alone does not imply it —
    /// it describes the *quotient*, not the slot content — but by the
    /// invariant above OCCUPIED-only slots hold their own run start.)
    #[inline(always)]
    fn holds_element(&self, i: usize) -> bool {
        !self.is_empty_slot(i)
    }

    /// Start of the supercluster containing `i`: walk left while the
    /// previous slot holds an element. Caller ensures some empty slot
    /// exists (cap < size).
    fn supercluster_start(&self, i: usize) -> usize {
        let mut j = i as isize;
        let mut steps = 0;
        while self.holds_element(self.idx(j - 1)) {
            j -= 1;
            steps += 1;
            debug_assert!(steps <= self.size(), "no empty slot in region");
            if steps > self.size() {
                break;
            }
        }
        self.idx(j)
    }

    /// Decode the supercluster starting at `start` (start must hold an
    /// element or the result is empty): returns runs as
    /// `(quotient, remainders)` in physical order, plus the span length.
    fn decode(&self, start: usize) -> (Vec<(usize, Vec<u64>)>, usize) {
        let mut runs: Vec<(usize, Vec<u64>)> = Vec::new();
        // Pending occupied quotients seen so far, in order; each run
        // start (CONTINUATION == 0) consumes the next one.
        let mut pending: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
        let mut span = 0usize;
        let mut j = start as isize;
        loop {
            let i = self.idx(j);
            if span >= self.size() || self.is_empty_slot(i) {
                break;
            }
            if self.slots[i] & OCCUPIED != 0 {
                pending.push_back(i);
            }
            let is_run_start = self.slots[i] & CONTINUATION == 0;
            if is_run_start {
                let q = pending
                    .pop_front()
                    .expect("run start without pending occupied quotient");
                runs.push((q, vec![self.rem_of(self.slots[i])]));
            } else {
                runs.last_mut()
                    .expect("continuation before any run start")
                    .1
                    .push(self.rem_of(self.slots[i]));
            }
            j += 1;
            span += 1;
        }
        // Trailing occupied bits with runs further right would belong to
        // the next supercluster only if... they can't: a quotient's run
        // lives in the supercluster containing its canonical slot.
        debug_assert!(pending.is_empty(), "dangling occupied quotients");
        (runs, span)
    }

    /// Write `runs` (sorted by quotient in canonical circular order from
    /// `anchor`) back, clearing at least `old_span` slots first. Runs are
    /// placed greedily: each run starts at max(its quotient, previous
    /// write position).
    fn rebuild(&mut self, anchor: usize, old_span: usize, runs: &[(usize, Vec<u64>)]) {
        // Clear old region (span may grow by one on insert; clearing the
        // old span suffices because writes cover the new span).
        for d in 0..old_span {
            let i = self.idx(anchor as isize + d as isize);
            self.slots[i] = 0;
        }
        // Rewrite. Positions are tracked in *unwrapped* coordinates
        // relative to anchor to keep the circular ordering sound.
        let size = self.size() as isize;
        let a = anchor as isize;
        let unwrap = move |q: usize| -> isize {
            let qq = q as isize;
            if qq >= a {
                qq
            } else {
                qq + size
            }
        };
        let mut write: isize = isize::MIN;
        for (q, rems) in runs {
            let canon = unwrap(*q);
            let begin = if write == isize::MIN { canon } else { canon.max(write) };
            for (k, rem) in rems.iter().enumerate() {
                let pos = begin + k as isize;
                let i = self.idx(pos);
                let mut meta = 0u64;
                if k > 0 {
                    meta |= CONTINUATION;
                }
                if pos != canon {
                    meta |= SHIFTED;
                }
                debug_assert!(self.slots[i] & !OCCUPIED == 0, "rebuild overwrote live slot");
                self.slots[i] = self.make_slot(*rem, meta) | (self.slots[i] & OCCUPIED);
            }
            // Mark the quotient occupied (bit lives on the canonical slot).
            self.slots[*q] |= OCCUPIED;
            write = begin + rems.len() as isize;
        }
    }

    fn insert(&mut self, q: usize, rem: u64) -> bool {
        if self.len >= self.cap {
            return false;
        }
        // Fast path: canonical slot empty → place directly.
        if self.is_empty_slot(q) && self.slots[q] & OCCUPIED == 0 {
            self.slots[q] = self.make_slot(rem, OCCUPIED);
            self.len += 1;
            return true;
        }
        // General path: decode the supercluster containing q, add, rebuild.
        let start = if self.holds_element(q) {
            self.supercluster_start(q)
        } else {
            // q's slot is empty but OCCUPIED is impossible here (invariant:
            // occupied quotient ⇒ its supercluster covers its slot).
            self.slots[q] = self.make_slot(rem, OCCUPIED);
            self.len += 1;
            return true;
        };
        let (mut runs, span) = self.decode(start);
        match runs.iter_mut().find(|(rq, _)| *rq == q) {
            Some((_, rems)) => {
                // Keep runs sorted for deterministic layout.
                let pos = rems.partition_point(|&r| r <= rem);
                rems.insert(pos, rem);
            }
            None => {
                // New quotient: insert run in circular canonical order.
                let unwrap = |x: usize| if x >= start { x } else { x + self.size() };
                let pos = runs.partition_point(|(rq, _)| unwrap(*rq) < unwrap(q));
                runs.insert(pos, (q, vec![rem]));
            }
        }
        self.rebuild(start, span, &runs);
        self.len += 1;
        true
    }

    fn contains(&self, q: usize, rem: u64) -> bool {
        if self.slots[q] & OCCUPIED == 0 {
            return false;
        }
        let start = self.supercluster_start(q);
        let (runs, _) = self.decode(start);
        runs.iter()
            .any(|(rq, rems)| *rq == q && rems.contains(&rem))
    }

    fn remove(&mut self, q: usize, rem: u64) -> bool {
        if self.slots[q] & OCCUPIED == 0 {
            return false;
        }
        let start = self.supercluster_start(q);
        let (mut runs, span) = self.decode(start);
        let Some(run_idx) = runs.iter().position(|(rq, _)| *rq == q) else {
            return false;
        };
        let Some(el_idx) = runs[run_idx].1.iter().position(|&r| r == rem) else {
            return false;
        };
        runs[run_idx].1.remove(el_idx);
        if runs[run_idx].1.is_empty() {
            runs.remove(run_idx);
            self.slots[q] &= !OCCUPIED;
        }
        self.rebuild(start, span, &runs);
        self.len -= 1;
        true
    }
}

/// The sharded, lockable quotient filter.
pub struct QuotientFilter {
    regions: Vec<Mutex<Region>>,
    region_bits: u32,
    q_bits: u32,
    r_bits: u32,
    seed: u64,
}

impl QuotientFilter {
    /// Build for `capacity` keys (95% fill ceiling), `r_bits` remainder
    /// bits. The paper's space-equivalent configuration uses a 16-bit
    /// remainder.
    pub fn with_capacity(capacity: usize) -> Self {
        Self::new(capacity, 16)
    }

    pub fn new(capacity: usize, r_bits: u32) -> Self {
        let slots_needed = ((capacity as f64 / 0.95).ceil() as usize).next_power_of_two();
        let total_q = slots_needed.trailing_zeros().max(8);
        // Shard into regions of ~2^14 slots (the GQF's locking regions).
        let region_bits = total_q.saturating_sub(14).min(8);
        let q_bits = total_q - region_bits;
        let regions = (0..1usize << region_bits)
            .map(|_| Mutex::new(Region::new(q_bits)))
            .collect();
        Self {
            regions,
            region_bits,
            q_bits,
            r_bits,
            seed: DEFAULT_SEED,
        }
    }

    /// Map a key to (region, quotient, remainder).
    #[inline(always)]
    fn plan(&self, key: u64) -> (usize, usize, u64) {
        let h = xxhash64_u64(key, self.seed);
        let region = (h >> (64 - self.region_bits)) as usize & ((1 << self.region_bits) - 1);
        let q = (h as usize) & ((1 << self.q_bits) - 1);
        let rem = (h >> self.q_bits) & ((1u64 << self.r_bits) - 1);
        (region, q, rem)
    }

    pub fn len(&self) -> usize {
        self.regions.iter().map(|r| r.lock().unwrap().len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl AmqFilter for QuotientFilter {
    fn name(&self) -> &'static str {
        "gqf"
    }

    fn insert(&self, key: u64) -> bool {
        let (region, q, rem) = self.plan(key);
        self.regions[region].lock().unwrap().insert(q, rem)
    }

    fn contains(&self, key: u64) -> bool {
        let (region, q, rem) = self.plan(key);
        self.regions[region].lock().unwrap().contains(q, rem)
    }

    fn remove(&self, key: u64) -> bool {
        let (region, q, rem) = self.plan(key);
        self.regions[region].lock().unwrap().remove(q, rem)
    }

    fn bytes(&self) -> usize {
        // r-bit remainder + 3 metadata bits per slot (ideal packing; the
        // in-memory Vec<u64> trades space for simplicity, we report the
        // structure's design size like the paper does).
        let slots = self.regions.len() * (1usize << self.q_bits);
        slots * (self.r_bits as usize + 3) / 8
    }

    fn bits_per_entry(&self) -> f64 {
        (self.r_bits + 3) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::mix64;

    fn keys(n: usize, stream: u64) -> Vec<u64> {
        (0..n as u64).map(|i| mix64(i ^ (stream << 52))).collect()
    }

    #[test]
    fn region_direct_insert_query() {
        let mut r = Region::new(8);
        assert!(r.insert(5, 42));
        assert!(r.contains(5, 42));
        assert!(!r.contains(5, 43));
        assert!(!r.contains(6, 42));
    }

    #[test]
    fn region_collision_run_building() {
        let mut r = Region::new(8);
        // Same quotient, several remainders → one run with shifts.
        for rem in [7u64, 3, 9, 1] {
            assert!(r.insert(10, rem));
        }
        for rem in [1u64, 3, 7, 9] {
            assert!(r.contains(10, rem));
        }
        assert!(!r.contains(10, 2));
        // Neighbouring quotient displaced into shifted slots.
        assert!(r.insert(11, 100));
        assert!(r.contains(11, 100));
        assert!(r.contains(10, 9));
    }

    #[test]
    fn region_delete_restores_layout() {
        let mut r = Region::new(8);
        for rem in [7u64, 3, 9] {
            r.insert(20, rem);
        }
        r.insert(21, 5);
        r.insert(22, 6);
        assert!(r.remove(20, 3));
        assert!(!r.contains(20, 3));
        for (q, rem) in [(20, 7u64), (20, 9), (21, 5), (22, 6)] {
            assert!(r.contains(q, rem), "lost ({q},{rem}) after delete");
        }
        assert!(!r.remove(20, 3), "double delete must fail");
    }

    #[test]
    fn region_wraparound_cluster() {
        let mut r = Region::new(4); // 16 slots
        // Build a cluster that wraps past the end of the table.
        for rem in 1..=4u64 {
            assert!(r.insert(14, rem));
        }
        for rem in 10..=12u64 {
            assert!(r.insert(15, rem));
        }
        for rem in 1..=4u64 {
            assert!(r.contains(14, rem));
        }
        for rem in 10..=12u64 {
            assert!(r.contains(15, rem));
        }
        assert!(r.remove(14, 2));
        assert!(r.contains(15, 11));
        assert!(r.contains(14, 4));
    }

    #[test]
    fn filter_end_to_end() {
        let f = QuotientFilter::with_capacity(50_000);
        let ks = keys(50_000, 1);
        for &k in &ks {
            assert!(f.insert(k), "insert failed");
        }
        for &k in &ks {
            assert!(f.contains(k), "false negative");
        }
        for &k in &ks {
            assert!(f.remove(k), "remove failed");
        }
        for &k in &ks {
            assert!(!f.contains(k), "residue after delete");
        }
        assert_eq!(f.len(), 0);
    }

    #[test]
    fn duplicates_count_via_repetition() {
        let f = QuotientFilter::with_capacity(1000);
        assert!(f.insert(77));
        assert!(f.insert(77));
        assert!(f.remove(77));
        assert!(f.contains(77), "one copy must remain");
        assert!(f.remove(77));
        assert!(!f.contains(77));
    }

    #[test]
    fn fpr_is_very_low() {
        // Paper Fig. 4: GQF has the lowest FPR (< 0.002%).
        let f = QuotientFilter::with_capacity(100_000);
        for k in keys(100_000, 2) {
            f.insert(k);
        }
        let probes = keys(500_000, 888);
        let fp = probes.iter().filter(|&&k| f.contains(k)).count();
        let fpr = fp as f64 / probes.len() as f64;
        assert!(fpr < 0.0005, "fpr={fpr}");
    }

    #[test]
    fn concurrent_regions() {
        use crate::device::Device;
        let f = QuotientFilter::with_capacity(100_000);
        let d = Device::with_workers(8);
        let ks = keys(100_000, 3);
        let ok = super::super::common::run_batch(&f, &d, crate::op::OpKind::Insert, &ks);
        assert_eq!(ok, 100_000);
        assert_eq!(super::super::common::run_batch(&f, &d, crate::op::OpKind::Query, &ks), 100_000);
        let removed = super::super::common::run_batch(&f, &d, crate::op::OpKind::Delete, &ks);
        assert_eq!(removed, 100_000);
    }

    #[test]
    fn fills_toward_capacity() {
        let f = QuotientFilter::new(10_000, 16);
        let mut ok = 0;
        for k in keys(10_000, 4) {
            if f.insert(k) {
                ok += 1;
            }
        }
        assert_eq!(ok, 10_000);
    }
}
