//! The uniform AMQ interface all filters (ours and the baselines)
//! implement, plus the one batched driver ([`run_batch`]) that runs any
//! of them, for any [`OpKind`], on any [`Backend`] — the comparison
//! figures (fig3/4/8) all measure through this single entry point, so a
//! new baseline or a new backend never grows a per-op helper family.

use crate::device::{Backend, WarpCtx};
use crate::op::OpKind;

/// An approximate-membership-query structure with (optional) deletion.
/// All methods take `&self`: implementations are internally synchronised
/// (lock-free or locked), matching the GPU batch model where a single
/// structure is hammered by thousands of threads.
pub trait AmqFilter: Sync {
    /// Structure name for bench output (matches the paper's legends).
    fn name(&self) -> &'static str;

    /// Insert; returns false when the structure rejects the key
    /// (full / eviction budget exhausted).
    fn insert(&self, key: u64) -> bool;

    /// Approximate membership (no false negatives for inserted keys).
    fn contains(&self, key: u64) -> bool;

    /// Delete one instance. Returns false if unsupported or not found.
    fn remove(&self, key: u64) -> bool;

    /// Whether deletion is supported at all (false for Bloom variants).
    fn supports_delete(&self) -> bool {
        true
    }

    /// Backing-storage bytes (the paper's space metric).
    fn bytes(&self) -> usize;

    /// Effective false-positive knob for reporting: bits of fingerprint
    /// (or bits-per-key for Bloom variants).
    fn bits_per_entry(&self) -> f64;
}

/// Run one batched operation over any [`AmqFilter`] on any [`Backend`]
/// (stream 0), returning the hierarchical success count. The single
/// batched driver behind every comparison figure: the op is picked by
/// [`OpKind`], so insert/query/delete share one launch body instead of
/// three hand-copied free functions.
pub fn run_batch<B: Backend + ?Sized>(
    f: &dyn AmqFilter,
    backend: &B,
    op: OpKind,
    keys: &[u64],
) -> u64 {
    // Resolve the op once per batch (fn pointer), not once per item.
    let call: fn(&dyn AmqFilter, u64) -> bool = match op {
        OpKind::Insert => |f, k| f.insert(k),
        OpKind::Query => |f, k| f.contains(k),
        OpKind::Delete => |f, k| f.remove(k),
    };
    backend.run(0, keys.len(), &|ctx: &mut WarpCtx| {
        for i in ctx.range.clone() {
            ctx.tally(call(f, keys[i]));
        }
    })
}

/// Empirical FPR measurement (§5.3 protocol): query `probes` keys known
/// to be absent; the hit fraction is the false-positive rate.
pub fn empirical_fpr<B: Backend + ?Sized>(
    f: &dyn AmqFilter,
    backend: &B,
    negative_probes: &[u64],
) -> f64 {
    let fp = run_batch(f, backend, OpKind::Query, negative_probes);
    fp as f64 / negative_probes.len() as f64
}

impl<L: crate::filter::Layout> AmqFilter for crate::filter::CuckooFilter<L> {
    fn name(&self) -> &'static str {
        "cuckoo-gpu"
    }

    fn insert(&self, key: u64) -> bool {
        CuckooFilterExt::insert(self, key)
    }

    fn contains(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::contains(self, key)
    }

    fn remove(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::remove(self, key)
    }

    fn bytes(&self) -> usize {
        crate::filter::CuckooFilter::bytes(self)
    }

    fn bits_per_entry(&self) -> f64 {
        self.policy().effective_fp_bits() as f64
    }
}

/// Disambiguation shim: `CuckooFilter::insert` returns `Result`, the trait
/// wants `bool`.
trait CuckooFilterExt {
    fn insert(&self, key: u64) -> bool;
}

impl<L: crate::filter::Layout> CuckooFilterExt for crate::filter::CuckooFilter<L> {
    fn insert(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::insert(self, key).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{CuckooConfig, CuckooFilter, Fp16};

    #[test]
    fn cuckoo_through_trait_object() {
        let f = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(1000)).unwrap();
        let dyn_f: &dyn AmqFilter = &f;
        assert!(dyn_f.insert(1));
        assert!(dyn_f.contains(1));
        assert!(dyn_f.remove(1));
        assert!(!dyn_f.contains(1));
        assert_eq!(dyn_f.name(), "cuckoo-gpu");
        assert!(dyn_f.supports_delete());
        assert_eq!(dyn_f.bits_per_entry(), 16.0);
    }

    #[test]
    fn batched_trait_ops() {
        let device = crate::device::Device::with_workers(2);
        let f = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(10_000)).unwrap();
        let keys: Vec<u64> = (0..10_000u64).map(crate::util::prng::mix64).collect();
        assert_eq!(run_batch(&f, &device, OpKind::Insert, &keys), 10_000);
        assert_eq!(run_batch(&f, &device, OpKind::Query, &keys), 10_000);
        let negatives: Vec<u64> = (0..10_000u64)
            .map(|i| crate::util::prng::mix64(i + (1 << 40)))
            .collect();
        let fpr = empirical_fpr(&f, &device, &negatives);
        assert!(fpr < 0.02, "fp16 FPR should be tiny, got {fpr}");
        assert_eq!(run_batch(&f, &device, OpKind::Delete, &keys), 10_000);
    }

    #[test]
    fn run_batch_is_backend_generic() {
        // The same driver over a multi-pool topology backend.
        let topo = crate::device::DeviceTopology::with_pools(2, 2);
        let f = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(5_000)).unwrap();
        let keys: Vec<u64> = (0..5_000u64).map(crate::util::prng::mix64).collect();
        assert_eq!(run_batch(&f, &topo, OpKind::Insert, &keys), 5_000);
        assert_eq!(run_batch(&f, &topo, OpKind::Query, &keys), 5_000);
        assert_eq!(run_batch(&f, &topo, OpKind::Delete, &keys), 5_000);
    }
}
