//! The uniform AMQ interface all filters (ours and the baselines)
//! implement, plus batched helpers that run any of them through the
//! [`crate::device::Device`] launch engine.

use crate::device::Device;

/// An approximate-membership-query structure with (optional) deletion.
/// All methods take `&self`: implementations are internally synchronised
/// (lock-free or locked), matching the GPU batch model where a single
/// structure is hammered by thousands of threads.
pub trait AmqFilter: Sync {
    /// Structure name for bench output (matches the paper's legends).
    fn name(&self) -> &'static str;

    /// Insert; returns false when the structure rejects the key
    /// (full / eviction budget exhausted).
    fn insert(&self, key: u64) -> bool;

    /// Approximate membership (no false negatives for inserted keys).
    fn contains(&self, key: u64) -> bool;

    /// Delete one instance. Returns false if unsupported or not found.
    fn remove(&self, key: u64) -> bool;

    /// Whether deletion is supported at all (false for Bloom variants).
    fn supports_delete(&self) -> bool {
        true
    }

    /// Backing-storage bytes (the paper's space metric).
    fn bytes(&self) -> usize;

    /// Effective false-positive knob for reporting: bits of fingerprint
    /// (or bits-per-key for Bloom variants).
    fn bits_per_entry(&self) -> f64;
}

/// Batched operations over any [`AmqFilter`] via the device engine.
pub fn insert_batch(f: &dyn AmqFilter, device: &Device, keys: &[u64]) -> u64 {
    device.launch(keys.len(), |ctx| {
        for i in ctx.range.clone() {
            ctx.tally(f.insert(keys[i]));
        }
    })
}

pub fn contains_batch(f: &dyn AmqFilter, device: &Device, keys: &[u64]) -> u64 {
    device.launch(keys.len(), |ctx| {
        for i in ctx.range.clone() {
            ctx.tally(f.contains(keys[i]));
        }
    })
}

pub fn remove_batch(f: &dyn AmqFilter, device: &Device, keys: &[u64]) -> u64 {
    device.launch(keys.len(), |ctx| {
        for i in ctx.range.clone() {
            ctx.tally(f.remove(keys[i]));
        }
    })
}

/// Empirical FPR measurement (§5.3 protocol): query `probes` keys known
/// to be absent; the hit fraction is the false-positive rate.
pub fn empirical_fpr(f: &dyn AmqFilter, device: &Device, negative_probes: &[u64]) -> f64 {
    let fp = contains_batch(f, device, negative_probes);
    fp as f64 / negative_probes.len() as f64
}

impl<L: crate::filter::Layout> AmqFilter for crate::filter::CuckooFilter<L> {
    fn name(&self) -> &'static str {
        "cuckoo-gpu"
    }

    fn insert(&self, key: u64) -> bool {
        CuckooFilterExt::insert(self, key)
    }

    fn contains(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::contains(self, key)
    }

    fn remove(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::remove(self, key)
    }

    fn bytes(&self) -> usize {
        crate::filter::CuckooFilter::bytes(self)
    }

    fn bits_per_entry(&self) -> f64 {
        self.policy().effective_fp_bits() as f64
    }
}

/// Disambiguation shim: `CuckooFilter::insert` returns `Result`, the trait
/// wants `bool`.
trait CuckooFilterExt {
    fn insert(&self, key: u64) -> bool;
}

impl<L: crate::filter::Layout> CuckooFilterExt for crate::filter::CuckooFilter<L> {
    fn insert(&self, key: u64) -> bool {
        crate::filter::CuckooFilter::insert(self, key).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{CuckooConfig, CuckooFilter, Fp16};

    #[test]
    fn cuckoo_through_trait_object() {
        let f = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(1000)).unwrap();
        let dyn_f: &dyn AmqFilter = &f;
        assert!(dyn_f.insert(1));
        assert!(dyn_f.contains(1));
        assert!(dyn_f.remove(1));
        assert!(!dyn_f.contains(1));
        assert_eq!(dyn_f.name(), "cuckoo-gpu");
        assert!(dyn_f.supports_delete());
        assert_eq!(dyn_f.bits_per_entry(), 16.0);
    }

    #[test]
    fn batched_trait_ops() {
        let device = Device::with_workers(2);
        let f = CuckooFilter::<Fp16>::new(CuckooConfig::with_capacity(10_000)).unwrap();
        let keys: Vec<u64> = (0..10_000u64).map(|i| crate::util::prng::mix64(i)).collect();
        assert_eq!(insert_batch(&f, &device, &keys), 10_000);
        assert_eq!(contains_batch(&f, &device, &keys), 10_000);
        let negatives: Vec<u64> = (0..10_000u64)
            .map(|i| crate::util::prng::mix64(i + (1 << 40)))
            .collect();
        let fpr = empirical_fpr(&f, &device, &negatives);
        assert!(fpr < 0.02, "fp16 FPR should be tiny, got {fpr}");
        assert_eq!(remove_batch(&f, &device, &keys), 10_000);
    }
}
