//! Partitioned CPU Cuckoo Filter (PCF) baseline — the multi-threaded
//! CPU reference (Schmidt, Bandle & Giceva, VLDB'21) the paper runs on
//! its Xeon System C (§5.1).
//!
//! Classic CPU layout: bucket size b = 4, 16-bit fingerprints, DFS
//! eviction — and *partitioning*: the key space is split into independent
//! sub-filters, each guarded by a lock, so threads rarely contend. This
//! is exactly the design point the four-dimensional analysis paper
//! recommends for multi-core CPUs, and the structure whose throughput
//! Figure 3 compares against (32×–350× slower than Cuckoo-GPU).

use super::common::AmqFilter;
use crate::filter::hash::{xxhash64_u64, DEFAULT_SEED};
use crate::util::prng::{mix64, SplitMix64};
use std::sync::Mutex;

const BUCKET_SLOTS: usize = 4;
const MAX_EVICTIONS: usize = 500;

/// One partition: a small sequential cuckoo filter (b=4, fp16).
struct Partition {
    /// One u64 word *is* one bucket (4 × 16-bit tags).
    buckets: Vec<u64>,
    len: usize,
}

type L = crate::filter::swar::Fp16;
use crate::filter::swar::{first_lane, Layout};

impl Partition {
    fn new(num_buckets: usize) -> Self {
        Self {
            buckets: vec![0; num_buckets],
            len: 0,
        }
    }

    #[inline(always)]
    fn pair(&self, h: u64, seed: u64) -> (usize, usize, u64) {
        let m = self.buckets.len() as u64;
        let mut fp = (h >> 32) & L::LANE_MASK;
        fp += (fp == 0) as u64;
        let i1 = (h & 0xFFFF_FFFF) % m;
        let i2 = i1 ^ (mix64(fp ^ seed) % m);
        (i1 as usize, i2 as usize, fp)
    }

    fn try_insert(&mut self, bucket: usize, fp: u64) -> bool {
        let word = self.buckets[bucket];
        let mask = L::zero_mask(word);
        if mask == 0 {
            return false;
        }
        let lane = first_lane::<L>(mask);
        self.buckets[bucket] = L::replace(word, lane, fp);
        true
    }

    fn insert(&mut self, h: u64, seed: u64) -> bool {
        let (i1, i2, fp) = self.pair(h, seed);
        if self.try_insert(i1, fp) || self.try_insert(i2, fp) {
            self.len += 1;
            return true;
        }
        // DFS eviction.
        let mut rng = SplitMix64::new(h ^ 0xDEAD_BEEF);
        let mut bucket = if rng.next_u64() & 1 == 0 { i1 } else { i2 };
        let mut tag = fp;
        for _ in 0..MAX_EVICTIONS {
            let lane = rng.next_below(BUCKET_SLOTS as u64) as u32;
            let word = self.buckets[bucket];
            let victim = L::extract(word, lane);
            self.buckets[bucket] = L::replace(word, lane, tag);
            debug_assert_ne!(victim, 0);
            tag = victim;
            let m = self.buckets.len() as u64;
            bucket = ((bucket as u64) ^ (mix64(tag ^ seed) % m)) as usize;
            if self.try_insert(bucket, tag) {
                self.len += 1;
                return true;
            }
        }
        // Undo is impossible cheaply; classic implementations leak the
        // displaced item on failure. We report failure (caller counts).
        false
    }

    fn contains(&self, h: u64, seed: u64) -> bool {
        let (i1, i2, fp) = self.pair(h, seed);
        L::contains_tag(self.buckets[i1], fp) || L::contains_tag(self.buckets[i2], fp)
    }

    fn remove(&mut self, h: u64, seed: u64) -> bool {
        let (i1, i2, fp) = self.pair(h, seed);
        for b in [i1, i2] {
            let word = self.buckets[b];
            let mask = L::match_mask(word, fp);
            if mask != 0 {
                let lane = first_lane::<L>(mask);
                self.buckets[b] = L::replace(word, lane, 0);
                self.len -= 1;
                return true;
            }
        }
        false
    }
}

pub struct PartitionedCuckooFilter {
    partitions: Vec<Mutex<Partition>>,
    partition_bits: u32,
    seed: u64,
}

impl PartitionedCuckooFilter {
    pub fn with_capacity(capacity: usize) -> Self {
        Self::new(capacity, 128)
    }

    pub fn new(capacity: usize, partitions: usize) -> Self {
        let partitions = partitions.next_power_of_two();
        let partition_bits = partitions.trailing_zeros();
        let slots_needed = (capacity as f64 / 0.95).ceil() as usize;
        let buckets_per_part = (slots_needed / partitions)
            .div_ceil(BUCKET_SLOTS)
            .next_power_of_two()
            .max(2);
        Self {
            partitions: (0..partitions)
                .map(|_| Mutex::new(Partition::new(buckets_per_part)))
                .collect(),
            partition_bits,
            seed: DEFAULT_SEED,
        }
    }

    #[inline(always)]
    fn route(&self, key: u64) -> (usize, u64) {
        let h = xxhash64_u64(key, self.seed);
        // Partition by top bits; pass the rest through.
        let p = (h >> (64 - self.partition_bits)) as usize;
        (p, h)
    }

    pub fn len(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl AmqFilter for PartitionedCuckooFilter {
    fn name(&self) -> &'static str {
        "pcf"
    }

    fn insert(&self, key: u64) -> bool {
        let (p, h) = self.route(key);
        self.partitions[p].lock().unwrap().insert(h, self.seed)
    }

    fn contains(&self, key: u64) -> bool {
        let (p, h) = self.route(key);
        self.partitions[p].lock().unwrap().contains(h, self.seed)
    }

    fn remove(&self, key: u64) -> bool {
        let (p, h) = self.route(key);
        self.partitions[p].lock().unwrap().remove(h, self.seed)
    }

    fn bytes(&self) -> usize {
        self.partitions
            .iter()
            .map(|p| p.lock().unwrap().buckets.len() * 8)
            .sum()
    }

    fn bits_per_entry(&self) -> f64 {
        16.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::mix64 as mx;

    fn keys(n: usize, stream: u64) -> Vec<u64> {
        (0..n as u64).map(|i| mx(i ^ (stream << 28))).collect()
    }

    #[test]
    fn basic_roundtrip() {
        let f = PartitionedCuckooFilter::with_capacity(20_000);
        let ks = keys(20_000, 1);
        let mut ok = 0;
        for &k in &ks {
            ok += f.insert(k) as usize;
        }
        assert!(ok as f64 > ks.len() as f64 * 0.999, "inserted {ok}");
        let mut found = 0;
        for &k in &ks {
            found += f.contains(k) as usize;
        }
        assert!(found >= ok);
    }

    #[test]
    fn delete_works() {
        let f = PartitionedCuckooFilter::with_capacity(5_000);
        let ks = keys(5_000, 2);
        for &k in &ks {
            f.insert(k);
        }
        let n0 = f.len();
        for &k in &ks {
            f.remove(k);
        }
        assert!(f.len() < n0 / 100, "len after delete = {}", f.len());
    }

    #[test]
    fn partitions_balance() {
        let f = PartitionedCuckooFilter::new(100_000, 64);
        for k in keys(100_000, 3) {
            f.insert(k);
        }
        let sizes: Vec<usize> = f.partitions.iter().map(|p| p.lock().unwrap().len).collect();
        let avg = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        for &s in &sizes {
            assert!((s as f64) > avg * 0.7 && (s as f64) < avg * 1.3, "s={s} avg={avg}");
        }
    }

    #[test]
    fn b4_layout_one_word_per_bucket() {
        // Bucket = one u64 word with 4 fp16 lanes.
        let mut p = Partition::new(8);
        assert!(p.insert(0xAAAA_BBBB_0000_0001, 7));
        assert!(p.contains(0xAAAA_BBBB_0000_0001, 7));
        assert!(p.remove(0xAAAA_BBBB_0000_0001, 7));
        assert!(!p.contains(0xAAAA_BBBB_0000_0001, 7));
        assert_eq!(p.len, 0);
    }

    #[test]
    fn concurrent_threads() {
        use crate::device::Device;
        let f = PartitionedCuckooFilter::with_capacity(50_000);
        let d = Device::with_workers(8);
        let ks = keys(50_000, 4);
        let ok = super::super::common::run_batch(&f, &d, crate::op::OpKind::Insert, &ks);
        assert!(ok > 49_900);
        let hits = super::super::common::run_batch(&f, &d, crate::op::OpKind::Query, &ks);
        assert!(hits >= ok);
    }
}
