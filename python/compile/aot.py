"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never executes on the Rust
request path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--buckets 4096]
                          [--batch 4096] [--fp-bits 16] [--slots 16]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import FilterModel


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(model: FilterModel, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"model": model.meta(), "artifacts": {}}
    for name in FilterModel.GRAPHS:
        lowered = jax.jit(model.fn(name)).lower(*model.specs(name))
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = fname
        print(f"  {name}: {len(text)} chars -> {fname}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", type=int, default=4096)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--fp-bits", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--tile", type=int, default=1024)
    args = ap.parse_args()

    model = FilterModel(
        num_buckets=args.buckets,
        bucket_slots=args.slots,
        fp_bits=args.fp_bits,
        batch=args.batch,
        tile=args.tile,
    )
    print(f"lowering {len(FilterModel.GRAPHS)} graphs to {args.out_dir}")
    lower_all(model, args.out_dir)


if __name__ == "__main__":
    main()
