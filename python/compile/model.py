"""Layer-2 JAX model: the batched filter compute graphs, composed from
the Layer-1 Pallas kernels, in the form the Rust runtime executes.

Three exported graphs (all lowered once by ``aot.py``):

* ``query``  — ``(words, keys) -> hits``: the paper's read-only query
  path; calls the Pallas SWAR kernel and nothing else, so the whole
  request-path computation lives in the kernel;
* ``query_stats`` — same plus a fused hit-count reduction (the warp-level
  tally of §4.3 maps to an XLA fused sum);
* ``hash``   — ``keys -> (fp, i1, i2)``: mutation planning for the Rust
  coordinator's insert path;
* ``bloom_query`` — the GBBF baseline's read path.

The geometry (bucket count, batch size) is static per artifact — the
analogue of the paper's compile-time template configuration (§4.7). The
Rust side pads batches to the artifact's batch size.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.bloom_kernel import bloom_query_pallas
from .kernels.hash_kernel import hash_pallas
from .kernels.query_kernel import query_pallas


class FilterModel:
    """Static geometry + the jax functions over it."""

    def __init__(
        self,
        num_buckets=4096,
        bucket_slots=16,
        fp_bits=16,
        batch=4096,
        tile=1024,
        seed=ref.DEFAULT_SEED,
        bloom_k=3,
    ):
        assert num_buckets & (num_buckets - 1) == 0
        self.num_buckets = num_buckets
        self.bucket_slots = bucket_slots
        self.fp_bits = fp_bits
        self.words_per_bucket = bucket_slots * fp_bits // 64
        self.num_words = num_buckets * self.words_per_bucket
        self.batch = batch
        self.tile = tile
        self.seed = seed
        self.bloom_k = bloom_k
        # Same byte budget for the bloom artifact as the cuckoo table.
        self.bloom_words = self.num_words

    # -- graphs ----------------------------------------------------------
    def query(self, words, keys):
        """Membership flags for a batch (uint8)."""
        return query_pallas(
            words, keys, self.words_per_bucket, self.fp_bits, self.seed, self.tile
        )

    def query_stats(self, words, keys):
        """Flags plus fused positive-hit count (uint32)."""
        hits = self.query(words, keys)
        return hits, jnp.sum(hits.astype(jnp.uint32))

    def hash(self, keys):
        """(fp, i1, i2) planning vectors (uint32 each)."""
        return hash_pallas(keys, self.num_buckets, self.fp_bits, self.seed, self.tile)

    def bloom_query(self, words, keys):
        return bloom_query_pallas(words, keys, self.bloom_k, self.seed, self.tile)

    # -- example inputs for lowering --------------------------------------
    def specs(self, name):
        words = jax.ShapeDtypeStruct((self.num_words,), jnp.uint64)
        keys = jax.ShapeDtypeStruct((self.batch,), jnp.uint64)
        bloom_words = jax.ShapeDtypeStruct((self.bloom_words,), jnp.uint64)
        return {
            "query": (words, keys),
            "query_stats": (words, keys),
            "hash": (keys,),
            "bloom_query": (bloom_words, keys),
        }[name]

    def fn(self, name):
        f = {
            "query": self.query,
            "query_stats": self.query_stats,
            "hash": self.hash,
            "bloom_query": self.bloom_query,
        }[name]

        # Outputs must be a tuple for the rust loader (return_tuple=True).
        @functools.wraps(f)
        def tupled(*args):
            out = f(*args)
            return out if isinstance(out, tuple) else (out,)

        return tupled

    GRAPHS = ("query", "query_stats", "hash", "bloom_query")

    def meta(self):
        return {
            "num_buckets": self.num_buckets,
            "bucket_slots": self.bucket_slots,
            "fp_bits": self.fp_bits,
            "words_per_bucket": self.words_per_bucket,
            "num_words": self.num_words,
            "batch": self.batch,
            "tile": self.tile,
            "seed": self.seed,
            "bloom_k": self.bloom_k,
            "bloom_words": self.bloom_words,
        }
