"""Layer-1 Pallas kernel: blocked-Bloom query (the GBBF baseline's read
path), so the benchmark comparison can also run through the AOT/PJRT
pipeline end to end."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

u64 = jnp.uint64


def _bloom_kernel_body(num_blocks, k, seed):
    def kernel(words_ref, keys_ref, out_ref):
        keys = keys_ref[...]
        words = words_ref[...]
        block, h1, h2 = ref.bloom_plan(keys, num_blocks, seed)
        hit = jnp.ones(keys.shape, dtype=bool)
        base = block * u64(ref.BLOOM_BLOCK_WORDS)
        for i in range(k):
            bit = (h1 + h2 * u64(i)) % u64(ref.BLOOM_BLOCK_BITS)
            w = jnp.take(words, (base + bit // u64(64)).astype(jnp.int64))
            hit = hit & ((w >> (bit % u64(64))) & u64(1)).astype(bool)
        out_ref[...] = hit.astype(jnp.uint8)

    return kernel


def bloom_query_pallas(words, keys, k=8, seed=ref.DEFAULT_SEED, tile=1024):
    words = jnp.asarray(words, dtype=u64)
    keys = jnp.asarray(keys, dtype=u64)
    n = keys.shape[0]
    m_words = words.shape[0]
    num_blocks = m_words // ref.BLOOM_BLOCK_WORDS
    tile = min(tile, n)
    assert n % tile == 0

    kernel = _bloom_kernel_body(num_blocks, k, seed)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((m_words,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=True,
    )(words, keys)
