"""Layer-1 Pallas kernel: batched two-bucket SWAR membership query.

This is the paper's read-only hot path (Algorithm 2) expressed for the
TPU programming model (DESIGN.md §Hardware-Adaptation):

* the CUDA grid over keys becomes the Pallas ``grid`` with a tile of keys
  per step (``BlockSpec`` carves the key and output vectors);
* the 256-bit vectorised bucket loads become whole-bucket vector reads
  from the table (resident in kernel memory), consumed lane-wise by the
  VPU — the SWAR compare is identical bit math to the CUDA version;
* there is no thread divergence by construction: every key performs the
  same constant-shape compare over both candidate buckets (the paper's
  branch-free "constant-time arithmetic" formulation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

u64 = jnp.uint64


def _query_kernel_body(num_buckets, words_per_bucket, fp_bits, seed):
    """Build the kernel body with static table geometry."""

    def kernel(words_ref, keys_ref, out_ref):
        keys = keys_ref[...]
        words = words_ref[...]
        fp, i1, i2 = ref.candidates(keys, num_buckets, fp_bits, seed)

        def bucket_hit(b):
            hit = jnp.zeros(b.shape, dtype=bool)
            base = (b * u64(words_per_bucket)).astype(jnp.int64)
            # Static unroll over the bucket's words — the "unrolled loop
            # over the returned word sequence" of Algorithm 2.
            for j in range(words_per_bucket):
                w = jnp.take(words, base + j)
                hit = hit | (ref.match_mask(w, fp, fp_bits) != u64(0))
            return hit

        out_ref[...] = (bucket_hit(i1) | bucket_hit(i2)).astype(jnp.uint8)

    return kernel


def query_pallas(
    words,
    keys,
    words_per_bucket,
    fp_bits=16,
    seed=ref.DEFAULT_SEED,
    tile=1024,
):
    """Run the Pallas query kernel over a batch of keys.

    `words`: packed table snapshot (num_buckets * words_per_bucket u64).
    `keys`: (n,) u64, n divisible by `tile` (pad with any key).
    Returns (n,) uint8 membership flags.
    """
    words = jnp.asarray(words, dtype=u64)
    keys = jnp.asarray(keys, dtype=u64)
    n = keys.shape[0]
    m_words = words.shape[0]
    num_buckets = m_words // words_per_bucket
    tile = min(tile, n)
    assert n % tile == 0, f"batch {n} not divisible by tile {tile}"

    kernel = _query_kernel_body(num_buckets, words_per_bucket, fp_bits, seed)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((m_words,), lambda i: (0,)),  # whole table each step
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=True,
    )(words, keys)
