"""Layer-1 Pallas kernel: batched xxHash64 key planning.

Computes, per key, the fingerprint and both candidate bucket indices
(§4.3 step 1: xxHash64, upper 32 bits → fingerprint, lower 32 bits →
primary index, partial-key XOR for the alternate). The Rust coordinator
uses this artifact to offload hash planning for large mutation batches.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _hash_kernel_body(num_buckets, fp_bits, seed):
    def kernel(keys_ref, fp_ref, i1_ref, i2_ref):
        keys = keys_ref[...]
        fp, i1, i2 = ref.candidates(keys, num_buckets, fp_bits, seed)
        fp_ref[...] = fp.astype(jnp.uint32)
        i1_ref[...] = i1.astype(jnp.uint32)
        i2_ref[...] = i2.astype(jnp.uint32)

    return kernel


def hash_pallas(keys, num_buckets, fp_bits=16, seed=ref.DEFAULT_SEED, tile=1024):
    """(fp, i1, i2) per key as uint32 vectors."""
    keys = jnp.asarray(keys, dtype=jnp.uint64)
    n = keys.shape[0]
    tile = min(tile, n)
    assert n % tile == 0

    kernel = _hash_kernel_body(num_buckets, fp_bits, seed)
    out = jax.ShapeDtypeStruct((n,), jnp.uint32)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=(out, out, out),
        interpret=True,
    )(keys)
