"""Pure-jnp / pure-python correctness oracles for the Pallas kernels.

Everything here mirrors the Rust hot path bit-for-bit:

* ``xxh64_u64`` — xxHash64 specialised to one little-endian u64 key
  (== ``rust/src/filter/hash.rs::xxhash64_u64``);
* ``mix64`` — the SplitMix64 finaliser used for fingerprint spreading
  (== ``rust/src/util/prng.rs::mix64``);
* ``candidates`` — fingerprint + two bucket indices, XOR policy
  (== ``rust/src/filter/policy.rs``);
* ``query_ref`` — two-bucket SWAR membership over a packed-word table
  (== ``rust/src/filter/core.rs::contains``);
* ``bloom_query_ref`` — the blocked-Bloom baseline query
  (== ``rust/src/baselines/bbf.rs``).

The jnp versions are vectorised and run under ``jax_enable_x64``; the
``*_scalar`` versions are plain-python integer golden models used to test
the jnp versions themselves.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# ----------------------------------------------------------------------
# Constants (shared with the Rust side — see hash.rs / prng.rs)
# ----------------------------------------------------------------------
P64_1 = 0x9E3779B185EBCA87
P64_2 = 0xC2B2AE3D27D4EB4F
P64_3 = 0x165667B19E3779F9
P64_4 = 0x85EBCA77C2B2AE63
P64_5 = 0x27D4EB2F165667C5
DEFAULT_SEED = 0x5EEDCAFEF00DD00D
M64 = (1 << 64) - 1

u64 = jnp.uint64


def _c(x):
    """Lift a python int into a u64 scalar."""
    return jnp.asarray(x & M64, dtype=u64)


def rotl(x, r):
    return (x << u64(r)) | (x >> u64(64 - r))


def xxh64_u64(key, seed=DEFAULT_SEED):
    """xxHash64 of one (vector of) u64 key(s) — the fixed-8-byte path."""
    key = jnp.asarray(key, dtype=u64)
    h = _c(seed) + _c(P64_5) + u64(8)
    # round(0, key)
    k = rotl(key * _c(P64_2), 31) * _c(P64_1)
    h = h ^ k
    h = rotl(h, 27) * _c(P64_1) + _c(P64_4)
    # avalanche
    h = h ^ (h >> u64(33))
    h = h * _c(P64_2)
    h = h ^ (h >> u64(29))
    h = h * _c(P64_3)
    h = h ^ (h >> u64(32))
    return h


def mix64(z):
    """SplitMix64 finaliser (fingerprint spreading hash)."""
    z = jnp.asarray(z, dtype=u64)
    z = (z ^ (z >> u64(30))) * _c(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> u64(27))) * _c(0x94D049BB133111EB)
    return z ^ (z >> u64(31))


# ----------------------------------------------------------------------
# Partial-key cuckoo hashing (XOR policy) for fp_bits-wide tags
# ----------------------------------------------------------------------
def candidates(keys, num_buckets, fp_bits=16, seed=DEFAULT_SEED):
    """fingerprint + (i1, i2) for each key; XOR policy, power-of-two m."""
    assert num_buckets & (num_buckets - 1) == 0, "XOR policy needs 2^k buckets"
    h = xxh64_u64(keys, seed)
    lane_mask = _c((1 << fp_bits) - 1)
    fp = (h >> u64(32)) & lane_mask
    fp = fp + (fp == u64(0)).astype(u64)
    m = _c(num_buckets)
    i1 = (h & _c(0xFFFFFFFF)) % m
    spread = mix64(fp ^ _c(seed)) % m
    i2 = i1 ^ spread
    return fp, i1, i2


# ----------------------------------------------------------------------
# SWAR lane ops over packed u64 words (== swar.rs)
# ----------------------------------------------------------------------
def lane_consts(fp_bits):
    lanes = 64 // fp_bits
    lsbs = 0
    for i in range(lanes):
        lsbs |= 1 << (i * fp_bits)
    msbs = lsbs << (fp_bits - 1)
    return lanes, lsbs, msbs


def zero_mask(word, fp_bits=16):
    """Exact per-lane zero detector (same formula as swar.rs)."""
    _, _, msbs = lane_consts(fp_bits)
    low = _c(~msbs)
    word = jnp.asarray(word, dtype=u64)
    return ~(((word & low) + low) | word | low)


def match_mask(word, tag, fp_bits=16):
    _, lsbs, _ = lane_consts(fp_bits)
    pattern = jnp.asarray(tag, dtype=u64) * _c(lsbs)
    return zero_mask(word ^ pattern, fp_bits)


# ----------------------------------------------------------------------
# Whole-filter query reference
# ----------------------------------------------------------------------
def query_ref(words, keys, words_per_bucket, fp_bits=16, seed=DEFAULT_SEED):
    """Two-bucket membership for each key over the packed table `words`.

    `words` is the Rust table snapshot (num_buckets * words_per_bucket u64).
    Returns uint8 hits. Pure jnp — the oracle the Pallas kernel is tested
    against, and itself tested against `query_scalar`.
    """
    words = jnp.asarray(words, dtype=u64)
    num_buckets = words.shape[0] // words_per_bucket
    fp, i1, i2 = candidates(keys, num_buckets, fp_bits, seed)

    def bucket_hit(b):
        hit = jnp.zeros(b.shape, dtype=bool)
        base = b * u64(words_per_bucket)
        for j in range(words_per_bucket):
            w = jnp.take(words, (base + u64(j)).astype(jnp.int64))
            hit = hit | (match_mask(w, fp, fp_bits) != u64(0))
        return hit

    return (bucket_hit(i1) | bucket_hit(i2)).astype(jnp.uint8)


# ----------------------------------------------------------------------
# Blocked-Bloom reference (== bbf.rs)
# ----------------------------------------------------------------------
BLOOM_BLOCK_WORDS = 8
BLOOM_BLOCK_BITS = 512


def bloom_plan(keys, num_blocks, seed=DEFAULT_SEED):
    h = xxh64_u64(keys, seed)
    block = h % _c(num_blocks)
    h1 = h >> u64(32)
    h2 = (h >> u64(17)) | u64(1)
    return block, h1, h2


def bloom_query_ref(words, keys, k, seed=DEFAULT_SEED):
    """Blocked-Bloom membership; `words` = num_blocks*8 u64."""
    words = jnp.asarray(words, dtype=u64)
    num_blocks = words.shape[0] // BLOOM_BLOCK_WORDS
    block, h1, h2 = bloom_plan(keys, num_blocks, seed)
    hit = jnp.ones(jnp.asarray(keys).shape, dtype=bool)
    base = block * u64(BLOOM_BLOCK_WORDS)
    for i in range(k):
        bit = (h1 + h2 * u64(i)) % u64(BLOOM_BLOCK_BITS)
        widx = (base + bit // u64(64)).astype(jnp.int64)
        w = jnp.take(words, widx)
        hit = hit & ((w >> (bit % u64(64))) & u64(1)).astype(bool)
    return hit.astype(jnp.uint8)


# ----------------------------------------------------------------------
# Plain-python scalar golden models (test the jnp code itself)
# ----------------------------------------------------------------------
def xxh64_u64_scalar(key: int, seed: int = DEFAULT_SEED) -> int:
    def rotl_i(x, r):
        return ((x << r) | (x >> (64 - r))) & M64

    h = (seed + P64_5 + 8) & M64
    k = (rotl_i((key * P64_2) & M64, 31) * P64_1) & M64
    h ^= k
    h = (rotl_i(h, 27) * P64_1 + P64_4) & M64
    h ^= h >> 33
    h = (h * P64_2) & M64
    h ^= h >> 29
    h = (h * P64_3) & M64
    h ^= h >> 32
    return h


def mix64_scalar(z: int) -> int:
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return z ^ (z >> 31)


def candidates_scalar(key: int, num_buckets: int, fp_bits: int = 16, seed: int = DEFAULT_SEED):
    h = xxh64_u64_scalar(key, seed)
    fp = (h >> 32) & ((1 << fp_bits) - 1)
    fp += fp == 0
    i1 = (h & 0xFFFFFFFF) % num_buckets
    i2 = i1 ^ (mix64_scalar(fp ^ seed) % num_buckets)
    return fp, i1, i2


def query_scalar(words, key, words_per_bucket, fp_bits=16, seed=DEFAULT_SEED) -> bool:
    lanes = 64 // fp_bits
    lane_mask = (1 << fp_bits) - 1
    num_buckets = len(words) // words_per_bucket
    fp, i1, i2 = candidates_scalar(key, num_buckets, fp_bits, seed)
    for b in (i1, i2):
        for j in range(words_per_bucket):
            w = int(words[b * words_per_bucket + j])
            for lane in range(lanes):
                if (w >> (lane * fp_bits)) & lane_mask == fp:
                    return True
    return False
