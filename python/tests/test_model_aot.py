"""Layer-2 / AOT pipeline tests: model graphs lower to HLO text that the
xla_extension 0.5.1 parser accepts, shapes are as the manifest declares,
and the lowered graphs compute the same answers as the kernels."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref
from compile.model import FilterModel

RNG = np.random.RandomState(42)


@pytest.fixture(scope="module")
def small_model():
    return FilterModel(num_buckets=256, bucket_slots=16, fp_bits=16, batch=512, tile=128)


class TestModelGraphs:
    def test_query_shapes(self, small_model):
        m = small_model
        words = jnp.zeros((m.num_words,), dtype=jnp.uint64)
        keys = jnp.zeros((m.batch,), dtype=jnp.uint64)
        out = m.query(words, keys)
        assert out.shape == (m.batch,)
        assert out.dtype == jnp.uint8

    def test_query_stats_fused_count(self, small_model):
        m = small_model
        words = np.zeros(m.num_words, dtype=np.uint64)
        # Plant one fingerprint so exactly the matching keys hit.
        keys = RNG.randint(0, 2**63, m.batch, dtype=np.uint64)
        hits, count = m.query_stats(words, keys)
        assert int(count) == int(np.array(hits).sum())

    def test_hash_graph(self, small_model):
        m = small_model
        keys = RNG.randint(0, 2**63, m.batch, dtype=np.uint64)
        fp, i1, i2 = m.hash(keys)
        e_fp, e_i1, e_i2 = ref.candidates_scalar(int(keys[3]), m.num_buckets, m.fp_bits)
        assert (int(fp[3]), int(i1[3]), int(i2[3])) == (e_fp, e_i1, e_i2)

    def test_meta_consistency(self, small_model):
        m = small_model
        meta = m.meta()
        assert meta["num_words"] == meta["num_buckets"] * meta["words_per_bucket"]
        assert meta["words_per_bucket"] == meta["bucket_slots"] * meta["fp_bits"] // 64


class TestAotLowering:
    def test_lower_all_writes_artifacts(self, small_model):
        with tempfile.TemporaryDirectory() as d:
            manifest = aot.lower_all(small_model, d)
            for name in FilterModel.GRAPHS:
                path = os.path.join(d, f"{name}.hlo.txt")
                assert os.path.exists(path), name
                text = open(path).read()
                assert text.startswith("HloModule"), f"{name} is not HLO text"
                # No Mosaic custom-calls: interpret-mode lowering only.
                assert "mosaic" not in text.lower(), f"{name} has TPU custom-call"
            man = json.load(open(os.path.join(d, "manifest.json")))
            assert man["model"]["num_buckets"] == small_model.num_buckets
            assert set(man["artifacts"]) == set(FilterModel.GRAPHS)
            assert manifest["model"] == man["model"]

    def test_hlo_text_roundtrips_through_parser(self, small_model):
        # The exact gate the Rust loader applies: text → HloModuleProto.
        from jax._src.lib import xla_client as xc

        lowered = jax.jit(small_model.fn("query")).lower(*small_model.specs("query"))
        text = aot.to_hlo_text(lowered)
        # Round-trip through the python-side parser as a smoke test.
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
        )
        assert comp.as_hlo_text() == text


class TestEndToEndNumerics:
    """Fill a table with the scalar model, query through the *lowered*
    (jitted) graph, compare with the scalar oracle — the same contract the
    Rust integration test enforces through PJRT."""

    def test_lowered_query_equals_scalar(self, small_model):
        m = small_model
        lanes = 64 // m.fp_bits
        words = [0] * m.num_words
        fill = RNG.randint(0, 2**63, m.num_words * lanes // 2, dtype=np.uint64)
        for k in fill:
            fp, i1, i2 = ref.candidates_scalar(int(k), m.num_buckets, m.fp_bits)
            placed = False
            for b in (i1, i2):
                for j in range(m.words_per_bucket):
                    w = words[b * m.words_per_bucket + j]
                    for lane in range(lanes):
                        if (w >> (lane * m.fp_bits)) & 0xFFFF == 0:
                            words[b * m.words_per_bucket + j] = w | (
                                fp << (lane * m.fp_bits)
                            )
                            placed = True
                            break
                    if placed:
                        break
                if placed:
                    break
        words = np.array(words, dtype=np.uint64)
        probes = np.concatenate(
            [fill[: m.batch // 2], RNG.randint(0, 2**63, m.batch // 2, dtype=np.uint64)]
        )

        jitted = jax.jit(m.fn("query"))
        got = np.array(jitted(words, probes)[0])
        want = np.array(
            [ref.query_scalar(words, int(k), m.words_per_bucket, m.fp_bits) for k in probes],
            dtype=np.uint8,
        )
        np.testing.assert_array_equal(got, want)


if __name__ == "__main__":
    import sys

    sys.exit(pytest.main([__file__, "-q"]))
