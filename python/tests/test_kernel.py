"""Layer-1 correctness: Pallas kernels vs the pure-jnp/scalar oracles.

Hypothesis sweeps shapes, fingerprint widths and table geometries; the
scalar golden models pin the jnp code, and cross-language golden vectors
pin everything to the Rust implementation.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bloom_kernel import bloom_query_pallas
from compile.kernels.hash_kernel import hash_pallas
from compile.kernels.query_kernel import query_pallas

RNG = np.random.RandomState(0xC0FFEE)


# ----------------------------------------------------------------------
# Hash: jnp == scalar == Rust golden vectors
# ----------------------------------------------------------------------
class TestHash:
    @given(st.integers(min_value=0, max_value=(1 << 64) - 1))
    @settings(max_examples=300, deadline=None)
    def test_jnp_matches_scalar(self, key):
        assert int(ref.xxh64_u64(np.uint64(key))) == ref.xxh64_u64_scalar(key)

    @given(
        st.integers(min_value=0, max_value=(1 << 64) - 1),
        st.integers(min_value=0, max_value=(1 << 64) - 1),
    )
    @settings(max_examples=100, deadline=None)
    def test_seed_sensitivity(self, key, seed):
        a = ref.xxh64_u64_scalar(key, seed)
        b = ref.xxh64_u64_scalar(key, seed ^ 1)
        assert a != b  # astronomically unlikely to collide

    def test_rust_golden_vectors(self):
        # Pinned against rust/src/filter/hash.rs (xxhash64_u64 with the
        # byte-path equivalence test) — full-spec xxh64 of the 8 LE bytes.
        # Computed from the reference spec implementation.
        import struct

        def xxh64_bytes_ref(data: bytes, seed: int = 0) -> int:
            # Minimal spec implementation (tail path only; len < 32).
            P1, P2, P3 = ref.P64_1, ref.P64_2, ref.P64_3
            P4, P5, M = ref.P64_4, ref.P64_5, ref.M64

            def rotl(x, r):
                return ((x << r) | (x >> (64 - r))) & M

            h = (seed + P5 + len(data)) & M
            i = 0
            while i + 8 <= len(data):
                k = int.from_bytes(data[i : i + 8], "little")
                h ^= (rotl((k * P2) & M, 31) * P1) & M
                h = (rotl(h, 27) * P1 + P4) & M
                i += 8
            if i + 4 <= len(data):
                h ^= (int.from_bytes(data[i : i + 4], "little") * P1) & M
                h = (rotl(h, 23) * P2 + P3) & M
                i += 4
            while i < len(data):
                h ^= (data[i] * P5) & M
                h = (rotl(h, 11) * P1) & M
                i += 1
            h ^= h >> 33
            h = (h * P2) & M
            h ^= h >> 29
            h = (h * P3) & M
            h ^= h >> 32
            return h

        for key in [0, 1, 42, 2**64 - 1, 0xDEADBEEFCAFEBABE]:
            expect = xxh64_bytes_ref(struct.pack("<Q", key), ref.DEFAULT_SEED)
            assert ref.xxh64_u64_scalar(key) == expect

    def test_mix64_matches_rust(self):
        # rust/src/util/prng.rs splitmix golden (seed 1234567, 1st output):
        # state = 1234567 + GAMMA, output = mix64(state).
        gamma = 0x9E3779B97F4A7C15
        assert ref.mix64_scalar((1234567 + gamma) & ref.M64) == 6457827717110365317


# ----------------------------------------------------------------------
# SWAR: jnp lane ops vs per-lane recomputation
# ----------------------------------------------------------------------
class TestSwar:
    @given(
        st.integers(min_value=0, max_value=(1 << 64) - 1),
        st.sampled_from([8, 16, 32]),
    )
    @settings(max_examples=300, deadline=None)
    def test_zero_mask_exact(self, word, fp_bits):
        lanes, _, _ = ref.lane_consts(fp_bits)
        mask = int(ref.zero_mask(np.uint64(word), fp_bits))
        for lane in range(lanes):
            lane_val = (word >> (lane * fp_bits)) & ((1 << fp_bits) - 1)
            bit = (mask >> (lane * fp_bits + fp_bits - 1)) & 1
            assert bit == (1 if lane_val == 0 else 0), (
                f"word={word:#x} lane={lane} fp_bits={fp_bits}"
            )

    @given(
        st.integers(min_value=0, max_value=(1 << 64) - 1),
        st.integers(min_value=0, max_value=(1 << 16) - 1),
    )
    @settings(max_examples=300, deadline=None)
    def test_match_mask_fp16(self, word, tag):
        mask = int(ref.match_mask(np.uint64(word), np.uint64(tag), 16))
        for lane in range(4):
            lane_val = (word >> (lane * 16)) & 0xFFFF
            bit = (mask >> (lane * 16 + 15)) & 1
            assert bit == (1 if lane_val == tag else 0)


# ----------------------------------------------------------------------
# Query kernel: pallas == jnp-ref == scalar
# ----------------------------------------------------------------------
def build_table(keys, num_buckets, words_per_bucket, fp_bits):
    """Insert via the scalar model (first-fit, no eviction needed at low
    load); returns (words, inserted_keys)."""
    lanes = 64 // fp_bits
    words = [0] * (num_buckets * words_per_bucket)
    inserted = []
    for k in keys:
        fp, i1, i2 = ref.candidates_scalar(int(k), num_buckets, fp_bits)
        placed = False
        for b in (i1, i2):
            for j in range(words_per_bucket):
                w = words[b * words_per_bucket + j]
                for lane in range(lanes):
                    if (w >> (lane * fp_bits)) & ((1 << fp_bits) - 1) == 0:
                        words[b * words_per_bucket + j] = w | (fp << (lane * fp_bits))
                        placed = True
                        break
                if placed:
                    break
            if placed:
                break
        if placed:
            inserted.append(int(k))
    return np.array(words, dtype=np.uint64), inserted


class TestQueryKernel:
    @given(
        st.sampled_from([8, 16, 32]),
        st.sampled_from([64, 256, 1024]),
        st.integers(min_value=1, max_value=4),
    )
    @settings(max_examples=24, deadline=None)
    def test_pallas_matches_ref(self, fp_bits, num_buckets, wpb_scale):
        lanes = 64 // fp_bits
        words_per_bucket = wpb_scale * (16 // lanes) if lanes <= 16 else wpb_scale
        words_per_bucket = max(1, words_per_bucket)
        n_fill = num_buckets * words_per_bucket * lanes // 2
        fill = RNG.randint(0, 2**63, max(n_fill, 4), dtype=np.uint64)
        words, _ = build_table(fill, num_buckets, words_per_bucket, fp_bits)

        probes = np.concatenate(
            [fill[:128], RNG.randint(0, 2**63, 128, dtype=np.uint64)]
        )
        probes = probes[:256]
        got = np.array(
            query_pallas(words, probes, words_per_bucket, fp_bits, tile=64)
        )
        want = np.array(ref.query_ref(words, probes, words_per_bucket, fp_bits))
        np.testing.assert_array_equal(got, want)

    def test_ref_matches_scalar(self):
        num_buckets, wpb, fp_bits = 128, 4, 16
        fill = RNG.randint(0, 2**63, 1500, dtype=np.uint64)
        words, inserted = build_table(fill, num_buckets, wpb, fp_bits)
        probes = np.concatenate([fill, RNG.randint(0, 2**63, 512, dtype=np.uint64)])
        want = np.array(
            [ref.query_scalar(words, int(k), wpb, fp_bits) for k in probes],
            dtype=np.uint8,
        )
        got = np.array(ref.query_ref(words, probes, wpb, fp_bits))
        np.testing.assert_array_equal(got, want)

    def test_no_false_negatives(self):
        num_buckets, wpb, fp_bits = 256, 4, 16
        fill = RNG.randint(0, 2**63, 2000, dtype=np.uint64)
        words, inserted = build_table(fill, num_buckets, wpb, fp_bits)
        probes = np.array(inserted[:1024], dtype=np.uint64)
        got = np.array(query_pallas(words, probes, wpb, fp_bits, tile=256))
        assert got.all(), "pallas kernel produced a false negative"

    def test_empty_table_all_negative(self):
        words = np.zeros(512, dtype=np.uint64)
        probes = RNG.randint(1, 2**63, 256, dtype=np.uint64)
        got = np.array(query_pallas(words, probes, 4, 16, tile=64))
        assert not got.any()

    @given(st.sampled_from([64, 128, 256, 512, 1024]))
    @settings(max_examples=10, deadline=None)
    def test_tile_size_invariance(self, tile):
        num_buckets, wpb, fp_bits = 128, 4, 16
        fill = RNG.randint(0, 2**63, 1000, dtype=np.uint64)
        words, _ = build_table(fill, num_buckets, wpb, fp_bits)
        probes = RNG.randint(0, 2**63, 1024, dtype=np.uint64)
        a = np.array(query_pallas(words, probes, wpb, fp_bits, tile=tile))
        b = np.array(ref.query_ref(words, probes, wpb, fp_bits))
        np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# Hash kernel
# ----------------------------------------------------------------------
class TestHashKernel:
    @given(st.sampled_from([256, 4096, 65536]), st.sampled_from([8, 16, 32]))
    @settings(max_examples=12, deadline=None)
    def test_matches_scalar(self, num_buckets, fp_bits):
        keys = RNG.randint(0, 2**63, 256, dtype=np.uint64)
        fp, i1, i2 = hash_pallas(keys, num_buckets, fp_bits, tile=64)
        for idx in [0, 17, 100, 255]:
            e_fp, e_i1, e_i2 = ref.candidates_scalar(
                int(keys[idx]), num_buckets, fp_bits
            )
            assert (int(fp[idx]), int(i1[idx]), int(i2[idx])) == (e_fp, e_i1, e_i2)

    def test_indices_in_range(self):
        keys = RNG.randint(0, 2**64, 1024, dtype=np.uint64)
        fp, i1, i2 = hash_pallas(keys, 4096, 16, tile=256)
        assert (np.array(i1) < 4096).all()
        assert (np.array(i2) < 4096).all()
        assert (np.array(fp) > 0).all()
        assert (np.array(fp) <= 0xFFFF).all()


# ----------------------------------------------------------------------
# Bloom kernel
# ----------------------------------------------------------------------
class TestBloomKernel:
    def _build(self, keys, num_blocks, k):
        words = np.zeros(num_blocks * ref.BLOOM_BLOCK_WORDS, dtype=np.uint64)
        block, h1, h2 = (
            np.array(x) for x in ref.bloom_plan(keys, num_blocks)
        )
        for b, a1, a2 in zip(block, h1, h2):
            for i in range(k):
                bit = (int(a1) + int(a2) * i) % ref.BLOOM_BLOCK_BITS
                widx = int(b) * ref.BLOOM_BLOCK_WORDS + bit // 64
                words[widx] |= np.uint64(1 << (bit % 64))
        return words

    def test_pallas_matches_ref(self):
        keys = RNG.randint(0, 2**63, 512, dtype=np.uint64)
        words = self._build(keys, 64, 8)
        probes = np.concatenate([keys[:256], RNG.randint(0, 2**63, 256, dtype=np.uint64)])
        got = np.array(bloom_query_pallas(words, probes, k=8, tile=128))
        want = np.array(ref.bloom_query_ref(words, probes, k=8))
        np.testing.assert_array_equal(got, want)

    def test_no_false_negatives(self):
        keys = RNG.randint(0, 2**63, 1000, dtype=np.uint64)
        words = self._build(keys, 128, 8)
        got = np.array(bloom_query_pallas(words, keys[:512], k=8, tile=256))
        assert got.all()


if __name__ == "__main__":
    import sys

    sys.exit(pytest.main([__file__, "-q"]))
