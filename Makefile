# Repo tooling. The Rust crate builds with plain cargo (std only);
# `make artifacts` is the one Python step, lowering the JAX model to
# the AOT HLO-text artifacts the native interpreter executes
# (rust/src/runtime/interp/). Requires jax on the Python side only —
# Python never runs on the Rust request path.

ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts fixture

# Serving-scale artifact set (defaults: 4096 buckets x 16 slots,
# batch 4096). Point `repro serve --backend aot --artifacts $(ARTIFACTS_DIR)`
# at the output.
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

# Regenerate the checked-in golden fixture consumed by `cargo test`
# (tiny geometry: 64 buckets x 16 slots, batch 128, tile 64). Only
# needed when the lowering in python/compile/ changes; the fixture is
# committed so tests run with no Python step.
fixture:
	cd python && python -m compile.aot --out-dir ../rust/tests/fixtures/aot_64 \
	  --buckets 64 --slots 16 --batch 128 --tile 64
